"""Deterministic chaos-soak harness for the serving cluster.

PR 6–9 proved each failure mode in isolation — one injected fault, one
crash, one partition per test.  This module composes those same fault
sites (``serve.faults.FaultInjector``, ``ShipChannel`` transport faults,
replica crashes, primary partitions) into *seeded, time-compressed
scenarios* and checks the robustness invariants after every event, so the
PR-10 control loops are proven under compound storms, not unit faults.

A :class:`Scenario` is a schedule: ``steps`` rounds of seeded load
(router reads, loop-submitted classed requests, topology mutations — all
drawn from one ``numpy`` generator seeded by ``Scenario.seed``) with
:class:`ChaosEvent` actions pinned to step indices (arm/disarm a fault
site, crash/rejoin a follower, partition or crash the primary, force the
heartbeat-lapse failover, rewrite an SLO budget, advance the virtual
clock).  :class:`ChaosHarness` executes it against a real
``ClusterCoordinator`` and returns a :class:`ChaosReport`.

**Determinism.**  Every control decision in a scenario runs on the
harness's :class:`VirtualClock` (``ControlConfig.clock``): breaker
cooldowns and brownout controller windows advance only when the schedule
says so, never with the wall.  Workload, mutations and event order are
seed-fixed; the policy's wall-coupled triggers (workload drift, ipt
regression) are disabled so invocation timing is a pure function of the
tick/mutation stream; and where a decision would depend on a measured
latency *value* (brownout breach), scenarios manipulate the budget
instead (``set_budget`` to ``1e-6`` / ``1e9``) so the comparison outcome
is value-independent.  The report's digest therefore covers exactly the
state that must be bit-reproducible — graph arrays, partition vector,
dirty mask, RNG state, invocation/seq/epoch counters, and a quiesced
probe batch's answers — and running the same scenario twice must produce
identical digests (``tests/test_chaos.py`` asserts it).

**Invariants** (checked at quiesce, after healing everything):

* *no acked commit lost* — the highest journaled seq ever observed on a
  healthy primary survives every crash/partition/failover;
* *staleness bounds honoured* — a spy on the router's serve path records
  any follower read whose version lag exceeded its class bound;
* *bitwise parity* — every live follower's replicated state (graph
  arrays, partition, dirty mask, RNG, invocation count) equals the
  primary's, and a probe batch answers identically on every replica;
* *evidence* — every fired fault site, every promotion/rejoin, every
  breaker transition and shed-level change left its event in the flight
  recorder (the black box tells the whole story).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.obs import Observability
from repro.serve.cluster import ClusterConfig, ClusterCoordinator
from repro.serve.control import ControlConfig
from repro.serve.faults import FaultInjector
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.utils import get_logger

log = get_logger("serve.chaos")

__all__ = ["ChaosEvent", "ChaosHarness", "ChaosReport", "Scenario",
           "SCENARIOS", "VirtualClock", "scenario"]

#: the probe workload every scenario serves and digests
PROBE_QUERIES = (parse_rpq("Area.Artist.(Artist|Label).Area"),
                 parse_rpq("Artist.Credit.Track.Medium"))


class VirtualClock:
    """Injectable monotonic clock: time moves only when the scenario says
    so, which is what makes breaker cooldowns and controller windows
    schedule-deterministic instead of wall-deterministic."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclass
class ChaosEvent:
    """One scheduled action.  ``step`` indexes the scenario round the
    action fires *before*; ``action`` is one of the harness verbs
    (``arm``, ``disarm``, ``crash_follower``, ``rejoin_follower``,
    ``crash_primary``, ``partition_primary``, ``heal_partition``,
    ``force_failover``, ``rejoin_demoted``, ``set_budget``,
    ``advance_clock``, ``set_load``)."""

    step: int
    action: str
    kwargs: Dict[str, Any] = field(default_factory=dict)


@dataclass
class Scenario:
    """A seeded, time-compressed fault storm (module doc)."""

    name: str
    seed: int = 0
    steps: int = 30
    events: List[ChaosEvent] = field(default_factory=list)
    n_followers: int = 2
    #: router reads per step (classed "hot"; the staleness spy watches)
    reads_per_step: int = 1
    #: requests submitted straight into the primary loop's queue per step
    #: (hot, cold) — the flash-crowd/brownout path
    loop_hot_per_step: int = 0
    loop_cold_per_step: int = 0
    #: probability a step also submits a topology mutation
    mutate_prob: float = 0.4
    #: built cluster size / graph seed
    n_vertices: int = 300
    graph_seed: int = 7
    #: control-loop knobs every scenario shares (clock injected at build)
    control: Optional[ControlConfig] = None
    #: extra ClusterConfig overrides
    cluster_kwargs: Dict[str, Any] = field(default_factory=dict)
    #: extra OnlinePolicy overrides (on top of the quiet deterministic
    #: policy — drift/ipt triggers at 9e9)
    policy_kwargs: Dict[str, Any] = field(default_factory=dict)


@dataclass
class ChaosReport:
    """Outcome of one scenario run."""

    scenario: str
    seed: int
    digest: str
    watermark_seq: int
    final_seq: int
    failovers: int
    rejoins: int
    epoch: int
    shed_raises: int
    breaker_trips: int
    faults_fired: Dict[str, int]
    staleness_violations: List[str]
    invariant_errors: List[str]
    stats: Dict[str, Any]

    @property
    def ok(self) -> bool:
        return not self.invariant_errors and not self.staleness_violations


class ChaosHarness:
    """Builds one observed, control-looped cluster and runs a scenario
    against it (module doc)."""

    def __init__(self, directory, sc: Scenario):
        self.sc = sc
        self.clock = VirtualClock()
        self.faults = FaultInjector()
        self.obs = Observability(trace_sample_rate=0.0,
                                 dump_dir=str(directory))
        ctl = sc.control or ControlConfig()
        #: the scenario's control config with the virtual clock injected —
        #: chaos runs must never let a breaker or controller read the wall
        from dataclasses import replace as dc_replace
        self.control = dc_replace(ctl, clock=self.clock)
        g = musicbrainz_like(sc.n_vertices, seed=sc.graph_seed)
        loop_cfg = ServeLoopConfig(
            micro_batch=8, overlap_invocations=False,
            snapshot_dir=str(directory), faults=self.faults, obs=self.obs,
            control=self.control)
        primary = ServingLoop(
            g, 4, taper_config=TaperConfig(max_iterations=2),
            policy=self._policy(), config=loop_cfg)
        ck = dict(heartbeat_timeout_s=9e9, faults=self.faults, obs=self.obs,
                  control=self.control, n_followers=sc.n_followers)
        ck.update(sc.cluster_kwargs)
        self.coord = ClusterCoordinator(
            primary, config=ClusterConfig(**ck), policy=self._policy(),
            taper_config=TaperConfig(max_iterations=2))
        self.rng = np.random.default_rng(sc.seed)
        self.watermark_seq = 0
        self.staleness_violations: List[str] = []
        self.invariant_errors: List[str] = []
        self._reads = 0
        self._hot = sc.loop_hot_per_step
        self._cold = sc.loop_cold_per_step
        self._spy_router()

    def _policy(self) -> OnlinePolicy:
        """Quiet deterministic policy: topology/cadence triggers only (the
        drift/ipt triggers depend on wall-measured values), pressure
        coupling on so overload defers invocations."""
        kw = dict(bootstrap_after_ticks=0, cadence=9_000_000, min_interval=0,
                  dirty_fraction=0.05, drift_l1=9e9, ipt_regression=9e9,
                  defer_above_pressure=0.45)
        kw.update(self.sc.policy_kwargs)
        return OnlinePolicy(**kw)

    def _spy_router(self) -> None:
        """Record (never mask) staleness-bound violations at serve time."""
        router = self.coord.router
        orig = router._serve_slot
        harness = self

        def spy(slot, queries, max_results):
            coord = harness.coord
            if slot != coord.primary_slot:
                f = coord.followers.get(slot)
                if f is not None:
                    bounds = coord.cfg.max_staleness_versions
                    bound = bounds.get(harness._cls,
                                       max(bounds.values(), default=0))
                    if f.version_lag > bound:
                        harness.staleness_violations.append(
                            f"slot {slot} served {harness._cls} at lag "
                            f"{f.version_lag} > bound {bound}")
            return orig(slot, queries, max_results)

        self._cls = "hot"
        router._serve_slot = spy

    # -- event verbs ----------------------------------------------------------
    def _apply_event(self, ev: ChaosEvent) -> None:
        coord, kw = self.coord, ev.kwargs
        log.info("chaos[%s] step-%d event: %s %s", self.sc.name, ev.step,
                 ev.action, kw)
        if ev.action == "arm":
            self.faults.arm(kw["site"], mode=kw.get("mode", "raise"),
                            times=kw.get("times", 1),
                            delay_s=kw.get("delay_s", 0.0))
        elif ev.action == "disarm":
            self.faults.disarm(kw.get("site"))
        elif ev.action == "crash_follower":
            coord.followers[kw["slot"]].crash()
        elif ev.action == "rejoin_follower":
            coord.followers[kw["slot"]].rejoin(
                reuse_state=kw.get("reuse_state", True))
        elif ev.action == "crash_primary":
            coord.crash_primary()
        elif ev.action == "partition_primary":
            coord.partition_primary()
        elif ev.action == "heal_partition":
            coord.hub.partition_primary(False)
        elif ev.action == "force_failover":
            # compress the heartbeat-lapse wait: backdate the last accepted
            # heartbeat so exactly one deterministic failover fires now
            coord.hub.last_heartbeat_mono = -9e9
            coord.cfg.heartbeat_timeout_s = 0.0
            assert coord.check_failover(), "forced failover did not fire"
            coord.cfg.heartbeat_timeout_s = 9e9
        elif ev.action == "rejoin_demoted":
            coord.rejoin_demoted(reuse_state=kw.get("reuse_state", True))
        elif ev.action == "set_budget":
            bo = coord.primary._brownout
            assert bo is not None, "set_budget needs control loops"
            bo.set_budget(kw["cls"], kw["budget_s"])
        elif ev.action == "advance_clock":
            self.clock.advance(kw["dt"])
        elif ev.action == "set_load":
            self._hot = kw.get("hot", self._hot)
            self._cold = kw.get("cold", self._cold)
        else:
            raise ValueError(f"unknown chaos action {ev.action!r}")

    # -- the drive loop -------------------------------------------------------
    def _primary_healthy(self) -> bool:
        return (not self.coord._primary_down
                and not self.coord.hub.primary_partitioned)

    def _drive_step(self, step: int) -> None:
        sc, coord = self.sc, self.coord
        q = PROBE_QUERIES[step % len(PROBE_QUERIES)]
        for _ in range(sc.reads_per_step):
            if self._primary_healthy():
                coord.serve([q], cls="hot")
                self._reads += 1
        # flash-crowd path: classed submissions into the primary queue
        # (brownout sheds these; rejected tickets simply never serve)
        for _ in range(self._hot):
            coord.primary.submit(q, cls="hot")
        for _ in range(self._cold):
            coord.primary.submit(PROBE_QUERIES[(step + 1) % 2], cls="cold")
        r = self.rng.random()
        if r < sc.mutate_prob and self._primary_healthy():
            n = coord.primary.g.n
            if r < sc.mutate_prob / 2:
                coord.submit_mutations(MutationBatch(
                    add_vertex_labels=[int(self.rng.integers(0, 4))],
                    add_edges=[(int(self.rng.integers(0, n)), n)]))
            else:
                coord.submit_mutations(MutationBatch(
                    add_edges=[(int(self.rng.integers(0, sc.n_vertices)),
                                int(self.rng.integers(0, sc.n_vertices)))]))
        coord.pump()
        # drain any loop-submitted backlog this step admitted
        for _ in range(8):
            if coord.primary.requests.depth() == 0:
                break
            coord.pump()
        if self._primary_healthy():
            self.watermark_seq = max(self.watermark_seq,
                                     int(self.coord.primary._applied_seq))

    def run(self) -> ChaosReport:
        """Execute the scenario, quiesce, check every invariant, digest."""
        by_step: Dict[int, List[ChaosEvent]] = {}
        for ev in self.sc.events:
            by_step.setdefault(ev.step, []).append(ev)
        for step in range(self.sc.steps):
            for ev in by_step.get(step, ()):
                self._apply_event(ev)
            self._drive_step(step)
        self.quiesce()
        self._check_invariants()
        report = self._report()
        self.coord.obs.recorder.trigger(f"chaos:{self.sc.name}")
        self.coord.stop()
        return report

    def quiesce(self) -> None:
        """Heal everything and converge: disarm all faults, lift any
        partition, drain queues, catch every live follower up to the
        journal head."""
        coord = self.coord
        self.faults.disarm()
        coord.hub.partition_primary(False)
        # let the brownout re-open fully: clear budgets + enough windows
        bo = coord.primary._brownout
        if bo is not None:
            for cls in list(bo.budgets):
                bo.set_budget(cls, 1e9)
        for _ in range(64):
            coord.pump()
            if bo is not None and coord.primary.requests.shed_level > 0:
                # each pump serves nothing new here; feed one classed probe
                # per shed class so the recovery windows have samples
                for cls in bo.cfg.shed_classes:
                    coord.primary.submit(PROBE_QUERIES[0], cls=cls)
                coord.primary.submit(PROBE_QUERIES[1], cls="hot")
                self.clock.advance(self.control.window_s + 1e-3)
            for f in coord.followers.values():
                if f.alive:
                    f.catch_up()
            if (coord.primary.requests.depth() == 0
                    and coord.primary.ingest.depth() == 0
                    and (bo is None
                         or coord.primary.requests.shed_level == 0)
                    and all(f.applied_seq == coord.hub.primary_seq
                            and f.version_lag == 0
                            for f in coord.followers.values() if f.alive)):
                return
        self.invariant_errors.append("quiesce did not converge in 64 rounds")

    # -- invariants -----------------------------------------------------------
    def _err(self, cond: bool, msg: str) -> None:
        if not cond:
            self.invariant_errors.append(msg)

    def _probe_answers(self, node) -> List:
        if isinstance(node, ServingLoop):
            return node.executor.enumerate_paths_many(
                list(PROBE_QUERIES), max_results=16, part=node.ot.part)
        return node.serve(list(PROBE_QUERIES), max_results=16)

    def _check_invariants(self) -> None:
        coord = self.coord
        # 1. no acked commit lost: everything journaled on a healthy
        # primary survived every crash, partition and promotion
        self._err(int(coord.primary._applied_seq) >= self.watermark_seq,
                  f"acked seq lost: primary at {coord.primary._applied_seq}"
                  f" < watermark {self.watermark_seq}")
        self._err(int(coord.hub.primary_seq) >= self.watermark_seq,
                  "hub head behind the acked watermark")
        # 2. bitwise parity: every live follower equals the primary
        a = coord.primary.ot
        probe = self._probe_answers(coord.primary)
        for slot, f in sorted(coord.followers.items()):
            if not f.alive:
                self.invariant_errors.append(
                    f"follower slot {slot} dead at quiesce")
                continue
            b = f.ot
            pairs = [("labels", a.g.labels, b.g.labels),
                     ("src", a.g.src, b.g.src), ("dst", a.g.dst, b.g.dst),
                     ("row_ptr", a.g.row_ptr, b.g.row_ptr),
                     ("part", a.part, b.part),
                     ("dirty", a._dirty, b._dirty)]
            for nm, x, y in pairs:
                self._err(np.array_equal(x, y),
                          f"slot {slot}: {nm} diverged from primary")
            self._err(a.g.version == b.g.version,
                      f"slot {slot}: graph version diverged")
            self._err(a.invocations == b.invocations,
                      f"slot {slot}: invocation count diverged")
            self._err(a.taper._rng.bit_generator.state
                      == b.taper._rng.bit_generator.state,
                      f"slot {slot}: RNG state diverged")
            self._err(self._probe_answers(f) == probe,
                      f"slot {slot}: probe answers diverged")
        # 3. evidence: the flight recorder holds the whole story
        rec = coord.obs.recorder
        fired = dict(self.faults.fired)
        fault_events = rec.events("fault_fired")
        for site in fired:
            self._err(any(e.get("site") == site for e in fault_events),
                      f"no fault_fired evidence for {site}")
        self._err(len(rec.events("promotion")) == coord.failovers,
                  "promotion events != failovers")
        self._err(len(rec.events("rejoin")) == coord.rejoins,
                  "rejoin events != rejoins")
        trips = self._breaker_trips()
        if trips:
            self._err(bool(rec.events("breaker_transition")),
                      "breakers tripped but no breaker_transition events")
        bo = coord.primary._brownout
        if bo is not None and bo.shed_raises:
            self._err(bool(rec.events("shed_level")),
                      "shed level moved but no shed_level events")

    def _breaker_trips(self) -> int:
        coord = self.coord
        trips = sum(b.trips for b in coord.router._breakers.values())
        trips += coord.primary._backend_breaker.trips
        for f in coord.followers.values():
            if getattr(f.channel, "breaker", None) is not None:
                trips += f.channel.breaker.trips
        return trips

    # -- digest / report ------------------------------------------------------
    def digest(self) -> str:
        """SHA-256 over the quiesced replicated state + probe answers —
        exactly the bytes that must reproduce for a fixed seed."""
        coord = self.coord
        h = hashlib.sha256()
        ot = coord.primary.ot
        for arr in (ot.g.labels, ot.g.src, ot.g.dst, ot.g.row_ptr, ot.part,
                    ot._dirty):
            h.update(np.ascontiguousarray(arr).tobytes())
        meta = (int(ot.g.n), int(ot.g.version), int(ot.invocations),
                int(coord.primary._applied_seq), int(coord.primary._epoch),
                int(coord.failovers), int(coord.rejoins),
                repr(ot.taper._rng.bit_generator.state))
        h.update(repr(meta).encode())
        h.update(repr(self._probe_answers(coord.primary)).encode())
        return h.hexdigest()

    def _report(self) -> ChaosReport:
        coord = self.coord
        bo = coord.primary._brownout
        return ChaosReport(
            scenario=self.sc.name,
            seed=self.sc.seed,
            digest=self.digest(),
            watermark_seq=self.watermark_seq,
            final_seq=int(coord.primary._applied_seq),
            failovers=coord.failovers,
            rejoins=coord.rejoins,
            epoch=int(coord.hub.current_epoch),
            shed_raises=(bo.shed_raises if bo is not None else 0),
            breaker_trips=self._breaker_trips(),
            faults_fired=dict(self.faults.fired),
            staleness_violations=list(self.staleness_violations),
            invariant_errors=list(self.invariant_errors),
            stats=coord.stats(),
        )


# ---------------------------------------------------------------------------
# the canonical scenarios
# ---------------------------------------------------------------------------


def _crash_storm() -> Scenario:
    """Follower crashes/rejoins stacking into a primary crash + failover,
    with apply-path faults firing throughout."""
    return Scenario(
        name="crash_storm", seed=11, steps=26, n_followers=2,
        mutate_prob=0.6,
        events=[
            ChaosEvent(3, "arm", {"site": "replica_apply:replica-2",
                                  "times": 1}),
            # the injected apply fault crashes replica-2; bring it back
            ChaosEvent(6, "rejoin_follower", {"slot": 2,
                                              "reuse_state": False}),
            ChaosEvent(5, "crash_follower", {"slot": 1}),
            ChaosEvent(9, "rejoin_follower", {"slot": 1,
                                              "reuse_state": True}),
            ChaosEvent(12, "crash_follower", {"slot": 1}),
            ChaosEvent(14, "rejoin_follower", {"slot": 1,
                                               "reuse_state": False}),
            ChaosEvent(17, "crash_primary", {}),
            ChaosEvent(17, "force_failover", {}),
            ChaosEvent(20, "rejoin_demoted", {"reuse_state": False}),
        ])


def _slow_follower() -> Scenario:
    """A permanently failing replica: its serve breaker trips, the router
    routes around it and suppresses hedges into it, and the half-open
    probe (virtual-clock cooldown) re-admits it after the fault clears."""
    ctl = ControlConfig(breaker_min_failures=2, breaker_error_rate=0.5,
                        breaker_cooldown_s=5.0)
    return Scenario(
        name="slow_follower", seed=23, steps=24, n_followers=2,
        mutate_prob=0.3, control=ctl,
        # hedging stays on but can never fire on latency (budget huge), so
        # the only routing changes are the deterministic breaker/fault ones
        cluster_kwargs={"slo_budget_s": {"hot": 9e9, "cold": 9e9}},
        events=[
            ChaosEvent(2, "arm", {"site": "replica_serve:replica-1",
                                  "times": -1}),
            # breaker trips after min_failures; cooldown is virtual time
            ChaosEvent(10, "disarm", {"site": "replica_serve:replica-1"}),
            ChaosEvent(12, "advance_clock", {"dt": 6.0}),
        ])


def _flash_crowd() -> Scenario:
    """4x classed overload into the primary queue: the brownout controller
    sheds cold traffic (budget forced breached), pressure defers the
    pending topology invocation, then recovery re-opens admission."""
    ctl = ControlConfig(shed_levels=2, clear_windows=1,
                        min_window_samples=2, window_s=0.25)
    return Scenario(
        name="flash_crowd", seed=37, steps=26, n_followers=1,
        reads_per_step=1, loop_hot_per_step=2, loop_cold_per_step=0,
        mutate_prob=0.5, control=ctl,
        events=[
            # overload: 4x hot + a cold stream, budget forced breached so
            # every controller window raises the shed level one step
            ChaosEvent(6, "set_load", {"hot": 8, "cold": 4}),
            ChaosEvent(6, "set_budget", {"cls": "hot", "budget_s": 1e-6}),
            ChaosEvent(7, "advance_clock", {"dt": 0.3}),
            ChaosEvent(9, "advance_clock", {"dt": 0.3}),
            ChaosEvent(11, "advance_clock", {"dt": 0.3}),
            # recovery: load drops, budget un-breaches, windows elapse
            ChaosEvent(14, "set_load", {"hot": 2, "cold": 1}),
            ChaosEvent(14, "set_budget", {"cls": "hot", "budget_s": 1e9}),
            ChaosEvent(15, "advance_clock", {"dt": 0.3}),
            ChaosEvent(17, "advance_clock", {"dt": 0.3}),
            ChaosEvent(19, "advance_clock", {"dt": 0.3}),
            ChaosEvent(21, "advance_clock", {"dt": 0.3}),
        ])


def _partition_heal() -> Scenario:
    """Primary partitioned mid-write: its late writes fence, a follower
    promotes, the healed zombie rejoins by catch-up replay and converges
    bitwise."""
    return Scenario(
        name="partition_heal", seed=53, steps=24, n_followers=2,
        mutate_prob=0.6,
        events=[
            ChaosEvent(8, "partition_primary", {}),
            ChaosEvent(10, "force_failover", {}),
            ChaosEvent(13, "heal_partition", {}),
            ChaosEvent(14, "rejoin_demoted", {"reuse_state": True}),
        ])


SCENARIOS: Dict[str, Callable[[], Scenario]] = {
    "crash_storm": _crash_storm,
    "slow_follower": _slow_follower,
    "flash_crowd": _flash_crowd,
    "partition_heal": _partition_heal,
}


def scenario(name: str) -> Scenario:
    """A fresh instance of one canonical scenario by name."""
    try:
        return SCENARIOS[name]()
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; have "
                         f"{sorted(SCENARIOS)}") from None


def run_scenario(directory, name: str) -> ChaosReport:
    """Convenience: build a harness under ``directory`` and run one
    canonical scenario end to end."""
    return ChaosHarness(directory, scenario(name)).run()
