"""Asynchronous graph-query serving loop with overlapped TAPER invocations.

The subsystem's control flow (see ``serve/README.md`` for the full
architecture note):

* **request path** — clients :meth:`ServingLoop.submit` RPQ requests into a
  bounded :class:`~repro.serve.queueing.RequestQueue`; the worker drains
  them in micro-batches and executes each batch through
  ``QueryExecutor.enumerate_paths_many`` (shared per-query enumeration
  plans) against the *current* partition vector;
* **ingest path** — topology deltas enter a bounded
  :class:`~repro.serve.ingest.IngestQueue`; the worker drains and coalesces
  them between invocations, applies them through
  ``LabelledGraph.apply_mutations`` (merge-patching every derived cache)
  and, under the ``pallas_sharded`` field backend, immediately re-uploads
  the dirty shard slices so device state stays warm before the next
  invocation;
* **invocation overlap** — every served micro-batch advances one
  ``OnlineTaper`` tick; when the policy fires, the invocation's inputs are
  snapshotted (``begin_invocation``) and the extroversion-field/swap run
  executes on a dedicated thread over the device mesh while the worker
  keeps serving against the **old** partition vector (double buffering).
  On completion the worker commits: one atomic rebind of the partition
  vector (readers see old or new, never a torn mix).  Ingest is deferred
  while a run is in flight — the graph must stay immutable under the field
  evaluation — which is exactly when the ingest queue's backpressure
  engages;
* **metrics** — per-request ipt and latency percentiles, queue depths and
  invocation stall/overlap accounting via
  :class:`~repro.serve.metrics.ServeMetrics`, exported as plain dicts.

``overlap_invocations=False`` degrades the same loop to the stop-the-world
baseline (the invocation runs inline on the worker, serving stalls) — the
comparison ``benchmarks/serve_loop.py`` quantifies.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.online import OnlinePolicy, OnlineTaper, PendingInvocation
from repro.core.rpq import RPQ
from repro.core.taper import TaperConfig
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.queueing import Rejection, RequestQueue, ServeTicket
from repro.utils import get_logger
from repro.workload.executor import QueryExecutor

log = get_logger("serve.loop")


@dataclass
class ServeLoopConfig:
    micro_batch: int = 16
    max_queue_depth: int = 256
    max_ingest_depth: int = 64
    max_results_per_query: int = 32
    #: run TAPER invocations on a dedicated thread, overlapped with serving
    #: (False = stop-the-world: the worker blocks for the whole invocation)
    overlap_invocations: bool = True
    #: minimum completed requests between consecutive invocations
    min_requests_between_invocations: int = 0
    #: completed requests before the first (bootstrap) invocation may fire
    first_invocation_after: int = 0
    #: how long an idle worker waits for requests before re-polling
    batch_wait_s: float = 0.005
    metrics_window: int = 2048


class ServingLoop:
    """Micro-batched serving engine over one mutable graph (module doc)."""

    def __init__(
        self,
        g: LabelledGraph,
        k: int,
        part: Optional[np.ndarray] = None,
        taper_config: Optional[TaperConfig] = None,
        policy: Optional[OnlinePolicy] = None,
        config: Optional[ServeLoopConfig] = None,
        sketch=None,
    ):
        self.cfg = config or ServeLoopConfig()
        if policy is None:
            # serving loops bootstrap their first fit from live traffic
            policy = OnlinePolicy(bootstrap_after_ticks=0)
        self.ot = OnlineTaper(
            g, k, part=part, config=taper_config, policy=policy,
            sketch=sketch)
        self.g = g
        self.k = k
        self.executor = QueryExecutor(g)
        # admission classes: the queue grades backpressure by per-query
        # sketch frequency (hot queries have warm plans/DP rows); the
        # frequency snapshot refreshes once per served micro-batch
        self._adm_freqs: Dict[str, float] = {}
        self.requests = RequestQueue(
            self.cfg.max_queue_depth,
            admission_weight=lambda q: self._adm_freqs.get(q.qhash, 0.0))
        self.ingest = IngestQueue(self.cfg.max_ingest_depth)
        self.metrics = ServeMetrics(self.cfg.metrics_window)
        self._pending: Optional[PendingInvocation] = None
        self._inflight: Optional[threading.Thread] = None
        self._invocation_done = threading.Event()
        self._invocation_t0 = 0.0
        self._invocation_error: Optional[BaseException] = None
        self._worker_error: Optional[BaseException] = None
        self._requests_since_invocation = 0
        self._ipt_ewma: Optional[float] = None
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None

    # -- client API -----------------------------------------------------------
    @property
    def part(self) -> np.ndarray:
        """The live partition vector (atomically rebound on commit)."""
        return self.ot.part

    def submit(self, query: RPQ) -> Union[ServeTicket, Rejection]:
        """Admit one request (any thread); see ``RequestQueue.submit``."""
        return self.requests.submit(query)

    def submit_mutations(self, batch: MutationBatch) -> Union[bool, Rejection]:
        """Queue one topology delta (any thread); applied by the worker
        between invocations."""
        return self.ingest.submit(batch)

    def stats(self) -> Dict[str, float]:
        return self.metrics.snapshot(
            queue_depth=self.requests.depth(),
            ingest_depth=self.ingest.depth(),
            rejected_requests=self.requests.rejected,
            rejected_cold_requests=self.requests.rejected_cold,
            rejected_mutations=self.ingest.rejected,
            failed_mutations=self.ingest.failed,
            field_stats=self.ot.taper._pre.get("_halo_stats"),
        )

    @property
    def invocation_in_flight(self) -> bool:
        return self._pending is not None

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ServingLoop":
        """Spawn the worker thread (threaded mode).  Alternatively drive the
        loop inline — no threads — by calling :meth:`pump` directly."""
        if self._worker is not None:
            raise RuntimeError("serving loop already started")
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._run, name="serve-worker", daemon=True)
        self._worker.start()
        return self

    def stop(self, drain: bool = True) -> Dict[str, float]:
        """Stop the worker; optionally drain queued requests/ingest first.
        Returns a final metrics snapshot.  Raises only when the *latest*
        invocation failed (earlier transient failures are counted in
        ``invocation_failures`` and logged when they happen, so a recovered
        blip does not surface as a stale exception hours later)."""
        self._stop.set()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._finish_inflight()
        if drain:
            while self._pump_once(wait_s=0.0, allow_trigger=False):
                pass
            self._apply_ingest()
        if self._worker_error is not None:
            raise self._worker_error
        if self._invocation_error is not None:
            raise self._invocation_error
        return self.stats()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self._pump_once(wait_s=self.cfg.batch_wait_s,
                                allow_trigger=True)
                self._worker_error = None   # healthy round: blip recovered
            except BaseException as exc:
                # a dead worker would silently wedge every outstanding
                # ticket; log, remember for stop() (cleared again by the
                # next healthy round, so only a *current* fault surfaces
                # there), and keep serving — the backoff stops a
                # persistent fault from spinning hot
                self._worker_error = exc
                log.exception("serve worker round failed")
                time.sleep(self.cfg.batch_wait_s)
        self._finish_inflight()

    # -- one scheduling round -------------------------------------------------
    def pump(self, wait_s: float = 0.0) -> int:
        """Inline drive: one scheduling round on the calling thread.
        Returns the number of requests served this round."""
        return self._pump_once(wait_s=wait_s, allow_trigger=True)

    def _pump_once(self, wait_s: float, allow_trigger: bool) -> int:
        self._commit_if_done()
        if self._pending is None:
            self._apply_ingest()
        batch = self.requests.take_batch(self.cfg.micro_batch, timeout=wait_s)
        if batch:
            self._serve_batch(batch)
            if allow_trigger:
                self._maybe_trigger()
        self._commit_if_done()
        return len(batch)

    def _serve_batch(self, batch: List[ServeTicket]) -> None:
        overlapped = (self._inflight is not None
                      and not self._invocation_done.is_set())
        queries = [t.query for t in batch]
        part = self.ot.part  # one read: stable for the whole micro-batch
        t0 = time.perf_counter()
        results = self.executor.enumerate_paths_many(
            queries, max_results=self.cfg.max_results_per_query, part=part)
        dt = time.perf_counter() - t0
        for ticket, (paths, crossings) in zip(batch, results):
            ticket.complete(paths, crossings)
        self.requests.record_service_time(dt / len(batch))
        self.metrics.record_batch(
            [t.latency_s for t in batch], [t.ipt for t in batch], overlapped)
        self.ot.observe(queries)
        # one snapshot per batch (O(#distinct queries)); admission reads it
        # lock-free via atomic rebind
        self._adm_freqs = self.ot.sketch.frequencies(self.ot.policy.min_freq)
        self._requests_since_invocation += len(batch)
        mean_ipt = float(np.mean([t.ipt for t in batch]))
        self._ipt_ewma = (mean_ipt if self._ipt_ewma is None
                          else 0.8 * self._ipt_ewma + 0.2 * mean_ipt)

    # -- invocation scheduling ------------------------------------------------
    def _maybe_trigger(self) -> None:
        reason = self.ot.poll(self._ipt_ewma)  # one tick per micro-batch
        if reason is None or self._pending is not None:
            return
        if self.ot.invocations == 0:
            if self.metrics.completed < self.cfg.first_invocation_after:
                return
        elif (self._requests_since_invocation
                < self.cfg.min_requests_between_invocations):
            return
        pending = self.ot.begin_invocation(reason)
        if pending is None:
            return
        self._pending = pending
        if self.cfg.overlap_invocations:
            self._invocation_done.clear()
            self._invocation_error = None   # only the latest run's outcome
            self._invocation_t0 = time.perf_counter()
            self._inflight = threading.Thread(
                target=self._invocation_main, name="serve-invocation",
                daemon=True)
            self._inflight.start()
        else:
            t0 = time.perf_counter()
            try:
                self.ot.run_invocation(pending)
            finally:
                # a failed run must not leave the loop looking mid-flight
                # (that would disable ingest and all future invocations);
                # the exception still propagates — to the inline caller, or
                # to _run's guard in threaded mode
                self._pending = None
            wall = time.perf_counter() - t0
            self.ot.commit_invocation(pending)
            self.metrics.record_invocation(wall, overlapped=False)
            self._requests_since_invocation = 0

    def _invocation_main(self) -> None:
        try:
            self.ot.run_invocation(self._pending)
        except BaseException as exc:  # surfaced by stop() if still latest
            self._invocation_error = exc
            self.metrics.record_invocation_failure()
            log.exception("overlapped TAPER invocation failed")
        finally:
            self._invocation_done.set()

    def _commit_if_done(self) -> None:
        if self._inflight is None or not self._invocation_done.is_set():
            return
        self._inflight.join()
        wall = time.perf_counter() - self._invocation_t0
        committed = False
        if self._pending is not None and self._pending.report is not None:
            self.ot.commit_invocation(self._pending)
            self.metrics.record_invocation(wall, overlapped=True)
            committed = True
        self._pending = None
        self._inflight = None
        self._requests_since_invocation = 0
        if committed:
            # the commit may have re-dealt the shard map along the enhanced
            # partition (shard_map_source="partition"); re-pack and upload
            # now, on the worker between batches, so the next overlapped
            # invocation starts from a warm re-dealt layout
            self._warm_devices()

    def _finish_inflight(self) -> None:
        if self._inflight is not None:
            self._invocation_done.wait()
            self._commit_if_done()

    # -- ingest ---------------------------------------------------------------
    def _apply_ingest(self) -> None:
        applied = 0
        for merged, members in self.ingest.drain_groups():
            try:
                self.ot.apply_mutations(merged)
                applied += 1
                continue
            except ValueError:
                # a malformed producer batch poisoned the fold; apply the
                # member batches individually so only the bad one is lost
                # (apply_mutations validates before touching any state, so
                # the failed fold left the graph untouched)
                log.exception(
                    "coalesced ingest group failed validation; retrying "
                    "its %d member batches individually", len(members))
            for b in members:
                try:
                    self.ot.apply_mutations(b)
                    applied += 1
                except ValueError:
                    self.ingest.failed += 1
                    log.exception("dropping malformed ingest batch")
        if applied:
            self._warm_devices()

    def _warm_devices(self) -> None:
        """Stream the freshly patched dirty shards onto the mesh now, off
        the invocation's critical path, so the next overlapped field
        evaluation starts from warm device buffers."""
        taper = self.ot.taper
        if taper.config.field_backend != "pallas_sharded":
            return
        import jax

        from repro.core.visitor import _sharded_device_arrays

        pre = taper._pre
        mesh = pre.get("_mesh")
        n_shards = (int(mesh.shape["model"]) if mesh is not None
                    else len(jax.devices()))
        token, order = pre.get("_shard_order") or ("stripe", None)
        sp = self.g.vm_packing_sharded(
            n_shards, cnt=self.g.cached_neighbor_label_counts(),
            order=order, order_token=token)
        _sharded_device_arrays(sp, pre)
