"""Asynchronous graph-query serving loop with overlapped TAPER invocations.

The subsystem's control flow (see ``serve/README.md`` for the full
architecture note):

* **request path** — clients :meth:`ServingLoop.submit` RPQ requests into a
  bounded :class:`~repro.serve.queueing.RequestQueue`; executor workers
  drain them in micro-batches and execute each batch through
  ``QueryExecutor.enumerate_paths_many`` (batched frontier enumeration,
  shared per-query plans) against the *current* partition vector.  With
  ``n_workers > 1`` the N workers drain the shared queue concurrently:
  worker 0 (the primary) keeps the whole control plane and quiesces the
  secondaries only while it mutates (ingest patch, partition commit);
* **ingest path** — topology deltas enter a bounded
  :class:`~repro.serve.ingest.IngestQueue`; the worker drains and coalesces
  them between invocations, applies them through
  ``LabelledGraph.apply_mutations`` (merge-patching every derived cache)
  and, under the ``pallas_sharded`` field backend, immediately re-uploads
  the dirty shard slices so device state stays warm before the next
  invocation;
* **invocation overlap** — every served micro-batch advances one
  ``OnlineTaper`` tick; when the policy fires, the invocation's inputs are
  snapshotted (``begin_invocation``) and the extroversion-field/swap run
  executes on a dedicated thread over the device mesh while the worker
  keeps serving against the **old** partition vector (double buffering).
  On completion the worker commits: one atomic rebind of the partition
  vector (readers see old or new, never a torn mix).  Ingest is deferred
  while a run is in flight — the graph must stay immutable under the field
  evaluation — which is exactly when the ingest queue's backpressure
  engages;
* **metrics** — per-request ipt and latency percentiles, queue depths and
  invocation stall/overlap accounting via
  :class:`~repro.serve.metrics.ServeMetrics`, exported as plain dicts.

``overlap_invocations=False`` degrades the same loop to the stop-the-world
baseline (the invocation runs inline on the worker, serving stalls) — the
comparison ``benchmarks/serve_loop.py`` quantifies.

Crash safety & graceful degradation (PR 6; ``serve/README.md`` has the
lifecycle diagram):

* **durability** — with ``snapshot_dir`` set, mutations are journaled on
  ingest *before* they apply: each drained coalesced group writes its
  members to the WAL, applies, then records the apply outcome
  (:class:`~repro.serve.snapshot.MutationJournal`), and each committed
  invocation persists a full serving snapshot on a background thread
  (:class:`~repro.serve.snapshot.ServingSnapshotter`).
  :meth:`ServingLoop.restore` = latest readable snapshot + WAL replay of
  the exact apply stream — bitwise parity with a node that never crashed;
* **watchdog** — an overlapped invocation exceeding
  ``invocation_timeout_s`` is cooperatively aborted (the run thread polls
  an abort flag at iteration boundaries) and abandoned; ingest and new
  invocations stay gated until the zombie thread actually exits (the
  enhancement ran against the live graph, which must stay immutable under
  it), while request serving continues on the old partition throughout;
* **backend ladder** — invocation failures feed a circuit breaker
  (``serve.control.Breaker``) whose trip — ``backend_fallback_after``
  failures in its window at the configured error rate, or that many
  consecutive failures (the historic strike count as the degenerate
  case) — walks ``field_backend`` one rung down ``FIELD_BACKEND_LADDER``
  (``pallas_sharded → pallas → jnp``: lose scale, keep availability);
  after ``backend_probe_after`` healthy commits the loop probes one rung
  back up, doubling the dwell after each failed probe so a flapping
  device converges to its stable rung;
* **fault injection** — a :class:`~repro.serve.faults.FaultInjector`
  (``ServeLoopConfig.faults``) arms the loop's named fault sites
  (invocation body, shard upload, coalesced ingest group) so tests and
  ``benchmarks/recovery.py`` can drive every degradation path on demand.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, replace as dc_replace
from pathlib import Path
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.online import OnlinePolicy, OnlineTaper, PendingInvocation
from repro.core.rpq import RPQ
from repro.core.taper import FIELD_BACKEND_LADDER, InvocationAborted, TaperConfig
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.serve.faults import (
    FaultInjector,
    InjectedFault,
    SITE_INGEST_GROUP,
    SITE_INVOCATION,
    SITE_SHARD_UPLOAD,
)
from repro.obs import Observability
from repro.obs.registry import Registry
from repro.obs.trace import NOOP_SPAN, NOOP_TRACE
from repro.serve.control import (
    Breaker,
    BrownoutController,
    ControlConfig,
    serve_pressure,
)
from repro.serve.ingest import IngestQueue
from repro.serve.metrics import ServeMetrics
from repro.serve.queueing import Rejection, RequestQueue, ServeTicket
from repro.serve.replication import FencedWrite, commit_payload
from repro.serve.snapshot import (
    MutationJournal,
    RestoreResult,
    ServingSnapshotter,
    WAL_NAME,
    capture_serving_state,
    restore_serving_state,
)
from repro.utils import get_logger
from repro.workload.executor import QueryExecutor

log = get_logger("serve.loop")


@dataclass
class ServeLoopConfig:
    micro_batch: int = 16
    max_queue_depth: int = 256
    max_ingest_depth: int = 64
    max_results_per_query: int = 32
    #: run TAPER invocations on a dedicated thread, overlapped with serving
    #: (False = stop-the-world: the worker blocks for the whole invocation)
    overlap_invocations: bool = True
    #: minimum completed requests between consecutive invocations
    min_requests_between_invocations: int = 0
    #: completed requests before the first (bootstrap) invocation may fire
    first_invocation_after: int = 0
    #: how long an idle worker waits for requests before re-polling
    batch_wait_s: float = 0.005
    metrics_window: int = 2048
    #: executor worker threads draining the request queue.  Worker 0 (the
    #: primary) owns the whole control plane — ingest, invocation trigger
    #: and commit, snapshots; workers 1.. only take_batch + serve.  Serving
    #: reads are lock-free (one atomic ``ot.part`` read per micro-batch);
    #: mutations quiesce the secondaries only for the pointer swap / patch
    n_workers: int = 1
    # -- durability (None = crash safety off, the pre-PR6 behaviour) ----------
    #: directory for snapshots + the mutation WAL
    snapshot_dir: Optional[str] = None
    #: persist a snapshot (async, off the worker) after every committed
    #: invocation — the commit already repacked device state, and the WAL
    #: window stays invocation-free, which is what recovery parity leans on
    snapshot_on_commit: bool = True
    #: additionally snapshot at this wall-clock period while quiescent
    snapshot_every_s: Optional[float] = None
    snapshot_keep: int = 3
    #: fsync the WAL on every append (power-loss durability; slower)
    wal_sync: bool = False
    # -- graceful degradation -------------------------------------------------
    #: abort an overlapped invocation running longer than this (None = off)
    invocation_timeout_s: Optional[float] = None
    #: base retry backoff after a failed invocation (doubles per
    #: consecutive failure)
    invocation_retry_backoff_s: float = 0.05
    #: consecutive invocation failures before falling one rung down the
    #: field-backend ladder
    backend_fallback_after: int = 2
    #: healthy commits at a degraded rung before probing back up
    backend_probe_after: int = 8
    #: fault-injection registry (tests / recovery benchmark)
    faults: Optional[FaultInjector] = None
    # -- observability (PR 9) -------------------------------------------------
    #: shared tracing/flight-recorder/registry bundle; None builds one from
    #: ``trace_sample_rate`` (or the shared disabled bundle at rate 0, the
    #: default — the hot path then pays a single attribute check)
    obs: Optional[Observability] = None
    #: request-trace sampling rate used when ``obs`` is not given
    #: (1.0 = every request, 0.0 = observability off)
    trace_sample_rate: float = 0.0
    # -- control loops (PR 10) -------------------------------------------------
    #: closed-loop overload protection (``serve.control``): brownout
    #: admission over live per-class latency quantiles, pressure-aware
    #: invocation cadence, and rate-based backend-breaker tuning.  None
    #: (the default) keeps the static thresholds — no control loops run,
    #: though the backend ladder still trips through a :class:`Breaker`
    #: whose parameters degenerate to the historic strike count.
    control: Optional[ControlConfig] = None


class ServingLoop:
    """Micro-batched serving engine over one mutable graph (module doc)."""

    def __init__(
        self,
        g: Optional[LabelledGraph] = None,
        k: Optional[int] = None,
        part: Optional[np.ndarray] = None,
        taper_config: Optional[TaperConfig] = None,
        policy: Optional[OnlinePolicy] = None,
        config: Optional[ServeLoopConfig] = None,
        sketch=None,
        ot: Optional[OnlineTaper] = None,
    ):
        self.cfg = config or ServeLoopConfig()
        if ot is not None:
            # restore path: adopt a fully reconstructed OnlineTaper verbatim
            self.ot = ot
        else:
            if g is None or k is None:
                raise ValueError("g and k are required unless ot= is given")
            if policy is None:
                # serving loops bootstrap their first fit from live traffic
                policy = OnlinePolicy(bootstrap_after_ticks=0)
            self.ot = OnlineTaper(
                g, k, part=part, config=taper_config, policy=policy,
                sketch=sketch)
        self.g = self.ot.g
        self.k = self.ot.k
        g = self.g
        self.executor = QueryExecutor(g)
        # admission classes: the queue grades backpressure by per-query
        # sketch frequency (hot queries have warm plans/DP rows); the
        # frequency snapshot refreshes once per served micro-batch
        self._adm_freqs: Dict[str, float] = {}
        self.requests = RequestQueue(
            self.cfg.max_queue_depth,
            admission_weight=lambda q: self._adm_freqs.get(q.qhash, 0.0))
        self.ingest = IngestQueue(self.cfg.max_ingest_depth)
        self.metrics = ServeMetrics(self.cfg.metrics_window)
        self._pending: Optional[PendingInvocation] = None
        self._inflight: Optional[threading.Thread] = None
        self._invocation_done = threading.Event()
        self._invocation_t0 = 0.0
        self._invocation_error: Optional[BaseException] = None
        self._worker_error: Optional[BaseException] = None
        self._requests_since_invocation = 0
        self._ipt_ewma: Optional[float] = None
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        # -- multi-worker serving ----------------------------------------------
        #: secondary executor threads (worker ids 1..n_workers-1)
        self._secondaries: List[threading.Thread] = []
        #: quiesce gate: secondaries serve inside _serving_section();
        #: mutators (primary only) close the gate and wait out in-flight
        #: batches before touching graph arrays or committing a partition
        self._gate = threading.Condition()
        self._gate_open = True
        self._active_serves = 0
        #: serialises the request-side observation state shared by all
        #: workers: the frequency sketch, admission freqs, the ipt EWMA and
        #: the invocation trigger counters (none of which are thread-safe)
        self._observe_lock = threading.Lock()
        # -- crash safety ------------------------------------------------------
        self._faults = self.cfg.faults
        self._journal: Optional[MutationJournal] = None
        self._snapshotter: Optional[ServingSnapshotter] = None
        #: WAL seq of the last coalesced group whose effect — applied or
        #: validation-dropped — is in the live state; snapshots record it
        #: so restore replays exactly the tail
        self._applied_seq = 0
        self._last_snapshot_t = time.monotonic()
        if self.cfg.snapshot_dir is not None:
            snap_dir = Path(self.cfg.snapshot_dir)
            self._journal = MutationJournal(snap_dir / WAL_NAME,
                                            sync=self.cfg.wal_sync)
            self._snapshotter = ServingSnapshotter(
                snap_dir, keep=self.cfg.snapshot_keep, journal=self._journal)
        # -- graceful degradation ---------------------------------------------
        #: the configured rung; anything below it counts as degraded
        self._base_backend = self.ot.taper.config.field_backend
        self._consec_invocation_failures = 0
        self._backoff_until = 0.0
        self._healthy_since_fallback = 0
        self._probe_after = self.cfg.backend_probe_after
        #: per-run cooperative-cancel flag (fresh Event per overlapped run)
        self._abort_flag = threading.Event()
        #: watchdog-abandoned invocation threads still winding down; ingest
        #: and new invocations are gated until they exit (the run reads the
        #: live graph, which must stay immutable under it)
        self._abandoned: List[threading.Thread] = []
        #: set by restore(); None on a fresh loop
        self.restore_result: Optional[RestoreResult] = None
        # -- replication (PR 8; None = single-node, zero behaviour change) -----
        #: cluster hub this loop publishes to as primary (attach_replication)
        self._replication = None
        #: the epoch this loop believes it holds the write lease for; a
        #: deposed primary keeps its stale epoch and gets fenced
        self._epoch = 1
        self._fenced_writes = 0
        self._fence_error: Optional[BaseException] = None
        # -- observability (PR 9) ----------------------------------------------
        if self.cfg.obs is not None:
            self.obs = self.cfg.obs
        elif self.cfg.trace_sample_rate > 0:
            self.obs = Observability(
                trace_sample_rate=self.cfg.trace_sample_rate)
        else:
            self.obs = Observability.disabled()
        self._obs_on = self.obs.enabled
        #: the in-flight (or just-committed) invocation's trace context;
        #: the coordinator also plants a failover trace here so the forced
        #: epoch-opening commit frame carries it across nodes
        self._invocation_ctx = NOOP_TRACE
        self._invocation_span = NOOP_SPAN
        if self._obs_on:
            # queue + fault injector only pay tracing costs when wired
            self.requests.tracer = self.obs.tracer
            self.requests.recorder = self.obs.recorder
            if self._faults is not None and self._faults.recorder is None:
                self._faults.recorder = self.obs.recorder
            self.ot.taper.tracer = self.obs.tracer
            # replace-on-reregister: a promoted loop takes over the dead
            # primary's collector slots on the shared registry
            self.obs.registry.register_collector("serve", self.collect)
            self.obs.registry.register_collector(
                "executor", self.executor.collect)
        # -- control loops (PR 10) ---------------------------------------------
        ctl = self.cfg.control
        clock = ctl.resolved_clock() if ctl is not None else time.monotonic
        #: the backend ladder's trip decision: error-rate-over-window with
        #: a consecutive-failure tail clause, so the historic
        #: ``backend_fallback_after`` strike count is the degenerate case
        self._backend_breaker = Breaker(
            "backend_ladder",
            window=max(ctl.breaker_window if ctl is not None else 16,
                       2 * self.cfg.backend_fallback_after),
            min_failures=self.cfg.backend_fallback_after,
            error_rate=(ctl.breaker_error_rate if ctl is not None else 0.5),
            recorder=(self.obs.recorder if self._obs_on else None),
            clock=clock)
        self._brownout: Optional[BrownoutController] = None
        self._ctl_registry: Optional[Registry] = None
        #: per-class request-latency histograms the brownout controller
        #: reads (lazily bound; only populated when control is configured)
        self._lat_hists: Dict[str, object] = {}
        #: EWMA of committed invocation wall time — the pressure signal's
        #: "traced invocation latency" input
        self._inv_wall_ewma = 0.0
        if ctl is not None:
            # brownout needs real histograms even when tracing is off; the
            # shared disabled bundle's registry must never be written to,
            # so an un-observed loop gets a private one
            self._ctl_registry = (self.obs.registry if self._obs_on
                                  else Registry())
            self._brownout = BrownoutController(
                self.requests, self._ctl_registry, ctl,
                recorder=(self.obs.recorder if self._obs_on else None))

    def collect(self) -> Dict[str, float]:
        """Metrics-registry collector: the loop's full SLO snapshot (the
        registry keeps numeric values and drops the string fields)."""
        return self.stats()

    def _inv_span(self, name: str, **attrs):
        """Span under the current invocation trace (no-op when unsampled)."""
        if not self._invocation_ctx.sampled:
            return NOOP_SPAN
        return self.obs.tracer.start(name, self._invocation_ctx, **attrs)

    def _clear_invocation_trace(self) -> None:
        self._invocation_ctx = NOOP_TRACE
        self._invocation_span = NOOP_SPAN
        self.ot.taper.trace_ctx = None

    # -- client API -----------------------------------------------------------
    @property
    def part(self) -> np.ndarray:
        """The live partition vector (atomically rebound on commit)."""
        return self.ot.part

    def submit(self, query: RPQ,
               cls: str = "hot") -> Union[ServeTicket, Rejection]:
        """Admit one request (any thread); see ``RequestQueue.submit``.
        ``cls`` is the request's SLO class (brownout shedding + per-class
        latency budgets when control loops are configured)."""
        return self.requests.submit(query, cls=cls)

    def submit_mutations(self, batch: MutationBatch) -> Union[bool, Rejection]:
        """Queue one topology delta (any thread); applied by the worker
        between invocations.  With durability on, the batch is journaled at
        the ingest drain, *before* it applies — the durability boundary is
        the next pump round's drain, not admission; producers needing a
        hard guarantee watch ``stats()["journal_seq"]`` advance."""
        return self.ingest.submit(batch)

    @property
    def degraded(self) -> bool:
        """True while serving below the configured field-backend rung."""
        return self.ot.taper.config.field_backend != self._base_backend

    # -- replication (primary side) -------------------------------------------
    def attach_replication(self, hub, epoch: Optional[int] = None) -> None:
        """Wire this loop up as the cluster primary.  Every durable write —
        journaling an ingest group, committing an invocation, publishing a
        snapshot — is first authorized against the hub's epoch fence (a
        :class:`~repro.serve.replication.FencedWrite` drops the write and
        is counted, never propagated into the serving path) and, once
        through, shipped to the followers (group/commit frames); each pump
        round heartbeats.  Unattached loops are bit-for-bit the single-node
        loop."""
        self._replication = hub
        self._epoch = int(epoch if epoch is not None else hub.current_epoch)
        if hub.journal is None and self._journal is not None:
            hub.journal = self._journal

    def observe_served(self, queries, ipts, latencies=None,
                       allow_trigger: bool = True) -> None:
        """Fold reads served *off-loop* (the cluster router answers most
        reads directly on follower replicas) into this loop's observation
        state — sketch, admission frequencies, ipt EWMA, tick/trigger
        counters — so TAPER invocations still see the whole cluster's
        query workload, not just the primary's slice."""
        if not queries:
            return
        if latencies is not None:
            self.metrics.record_batch(
                latencies, ipts,
                overlapped=(self._inflight is not None
                            and not self._invocation_done.is_set()))
        with self._observe_lock:
            self.ot.observe(queries)
            self._adm_freqs = self.ot.sketch.frequencies(
                self.ot.policy.min_freq)
            self._requests_since_invocation += len(queries)
            mean_ipt = float(np.mean(ipts)) if len(ipts) else 0.0
            self._ipt_ewma = (mean_ipt if self._ipt_ewma is None
                              else 0.8 * self._ipt_ewma + 0.2 * mean_ipt)
        if allow_trigger:
            self._maybe_trigger()

    def _note_fenced(self, exc: FencedWrite) -> None:
        self._fenced_writes += 1
        self._fence_error = exc
        self.obs.recorder.record("fence_rejection", epoch=self._epoch,
                                 error=repr(exc))
        log.warning("fenced write rejected: %s", exc)

    def _fenced_commit_guard(self) -> bool:
        """True when a durable commit may proceed (no replication attached,
        or the epoch fence authorized it)."""
        if self._replication is None:
            return True
        try:
            self._replication.authorize(self._epoch, "invocation commit")
            return True
        except FencedWrite as exc:
            self._note_fenced(exc)
            return False

    def _publish_commit(self, force: bool = False) -> None:
        """Ship the just-committed invocation's volatile state (partition
        vector, RNG, placement prior, counters) to the followers."""
        if self._replication is None:
            return
        payload = commit_payload(self.ot)
        if self._invocation_ctx.sampled:
            # piggyback the invocation (or failover) trace id on the frame
            # so the followers' applies join the originating trace
            payload["trace_id"] = self._invocation_ctx.trace_id
        try:
            self._replication.publish_commit(
                self._epoch, payload, self._applied_seq, force=force)
        except FencedWrite as exc:
            self._note_fenced(exc)

    def stats(self) -> Dict[str, float]:
        rep: Dict[str, object] = {}
        if self._replication is not None:
            hub = self._replication.stats()
            rep = dict(
                epoch=self._epoch,
                cluster_epoch=hub["epoch"],
                fenced_writes=self._fenced_writes,
                fencing_rejections=(hub["fencing_rejections"]
                                    + hub["partition_rejections"]),
                last_stale_epoch=hub["last_stale_epoch"],
                fence_error=("" if self._fence_error is None
                             else repr(self._fence_error)),
            )
        if self._snapshotter is not None:
            rep["snapshot_capture_s"] = self._snapshotter.last_capture_s
            rep["snapshot_publish_s"] = self._snapshotter.last_wall_s
        extra: Dict[str, object] = {}
        if self.cfg.control is not None:
            extra = {
                "shed_level": self.requests.shed_level,
                "rejected_brownout": self.requests.rejected_brownout,
                "serve_pressure": self._serve_pressure(),
                "pressure_deferrals": self.ot.pressure_deferrals,
                "backend_breaker_state": self._backend_breaker.state,
                "backend_breaker_trips": self._backend_breaker.trips,
            }
        return self.metrics.snapshot(
            extra=extra,
            queue_depth=self.requests.depth(),
            ingest_depth=self.ingest.depth(),
            rejected_requests=self.requests.rejected,
            rejected_cold_requests=self.requests.rejected_cold,
            rejected_mutations=self.ingest.rejected,
            failed_mutations=self.ingest.failed,
            field_stats=self.ot.taper._pre.get("_halo_stats"),
            field_backend=self.ot.taper.config.field_backend,
            degraded=self.degraded,
            worker_error=("" if self._worker_error is None
                          else repr(self._worker_error)),
            invocation_error=("" if self._invocation_error is None
                              else repr(self._invocation_error)),
            journal_seq=self._applied_seq,
            **rep,
        )

    @property
    def invocation_in_flight(self) -> bool:
        return self._pending is not None

    # -- durability -----------------------------------------------------------
    def snapshot(self, sync: bool = True) -> None:
        """Capture and persist the full serving state now.  Call from the
        worker thread (a pump round) or while the loop is stopped — the
        capture copies host state; with ``sync=False`` the write itself
        happens on the snapshotter's background thread."""
        if self._snapshotter is None:
            raise RuntimeError("snapshot_dir not configured")
        if self._replication is not None:
            # a zombie primary must not publish snapshots: a follower
            # bootstrapping from one would adopt state the cluster has
            # moved past under a newer epoch
            try:
                self._replication.authorize(self._epoch, "snapshot publish")
            except FencedWrite as exc:
                self._note_fenced(exc)
                self.metrics.record_snapshot(False)
                return
        try:
            with self._observe_lock:
                # the capture copies the sketch, which secondary workers
                # are concurrently observing into
                state = capture_serving_state(self.ot, self._applied_seq)
            self._snapshotter.save(state, sync=sync)
            self.metrics.record_snapshot(True)
            self._last_snapshot_t = time.monotonic()
        except BaseException:
            self.metrics.record_snapshot(False)
            log.exception("serving snapshot failed; continuing without")

    @classmethod
    def restore(
        cls,
        directory,
        taper_config: Optional[TaperConfig] = None,
        policy: Optional[OnlinePolicy] = None,
        config: Optional[ServeLoopConfig] = None,
        n_shards: Optional[int] = None,
        snap_id: Optional[int] = None,
    ) -> "ServingLoop":
        """Bring a crashed node back: latest readable snapshot under
        ``directory`` + WAL replay, then a loop serving that state.  Pass
        ``n_shards`` to restore onto a different shard count (elastic
        restore; the k→S shard fold is recomputed and
        ``restore_result.elastic_plan`` carries the byte-movement budget).
        The restored loop keeps journaling/snapshotting into the same
        directory and starts at the *configured* backend rung — a restart
        is the natural probe that a device fault has cleared."""
        cfg = config or ServeLoopConfig()
        if policy is None:
            policy = OnlinePolicy(bootstrap_after_ticks=0)
        if cfg.snapshot_dir is None:
            cfg = dc_replace(cfg, snapshot_dir=str(directory))
        res = restore_serving_state(
            directory, taper_config=taper_config, policy=policy,
            n_shards=n_shards, snap_id=snap_id)
        loop = cls(config=cfg, ot=res.ot)
        loop._applied_seq = res.journal_seq
        loop.metrics.replayed_mutations = res.replayed
        loop.restore_result = res
        if loop.ot.taper.config.field_backend == "pallas_sharded":
            # re-derive device-resident packings eagerly so the first
            # invocation after restart starts warm, like a running node's
            loop._warm_devices()
        return loop

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ServingLoop":
        """Spawn the worker thread (threaded mode).  Alternatively drive the
        loop inline — no threads — by calling :meth:`pump` directly."""
        if self._worker is not None:
            raise RuntimeError("serving loop already started")
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._run, name="serve-worker", daemon=True)
        self._worker.start()
        for wid in range(1, max(1, self.cfg.n_workers)):
            t = threading.Thread(
                target=self._serve_run, args=(wid,),
                name=f"serve-worker-{wid}", daemon=True)
            t.start()
            self._secondaries.append(t)
        return self

    def stop(self, drain: bool = True) -> Dict[str, float]:
        """Stop the worker; optionally drain queued requests/ingest first.
        Returns a final metrics snapshot.  Raises only when the *latest*
        invocation failed (earlier transient failures are counted in
        ``invocation_failures`` and logged when they happen, so a recovered
        blip does not surface as a stale exception hours later)."""
        self._stop.set()
        for t in self._secondaries:
            t.join()
        self._secondaries = []
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._finish_inflight()
        if drain:
            while self._pump_once(wait_s=0.0, allow_trigger=False):
                pass
            if not self._zombies_active():
                self._apply_ingest()
        if self._snapshotter is not None:
            self._snapshotter.close()
        if self._journal is not None:
            self._journal.close()
        if self._worker_error is not None:
            raise self._worker_error
        if self._invocation_error is not None:
            raise self._invocation_error
        return self.stats()

    def _serve_run(self, wid: int) -> None:
        """Secondary executor worker: take_batch + serve, nothing else.
        The control plane (ingest, invocations, snapshots) stays on the
        primary; a mutation there closes the gate, so a secondary is either
        idle, blocked at the gate, or serving against a stable graph."""
        while not self._stop.is_set():
            try:
                batch = self.requests.take_batch(
                    self.cfg.micro_batch, timeout=self.cfg.batch_wait_s)
                if not batch:
                    continue
                with self._serving_section():
                    self._serve_batch(batch, worker_id=wid)
                self._worker_error = None
            except BaseException as exc:
                self._worker_error = exc
                log.exception("serve worker %d round failed", wid)
                time.sleep(self.cfg.batch_wait_s)

    @contextmanager
    def _serving_section(self):
        """Secondary workers serve inside this: blocks while the gate is
        closed (a mutation in progress), counts the batch as in-flight so
        :meth:`_quiesced` can wait it out.  The gate always reopens —
        ``_quiesced`` restores it in a ``finally`` — so this never hangs."""
        with self._gate:
            while not self._gate_open:
                self._gate.wait(0.1)
            self._active_serves += 1
        try:
            yield
        finally:
            with self._gate:
                self._active_serves -= 1
                self._gate.notify_all()

    @contextmanager
    def _quiesced(self):
        """Primary-only: close the serving gate and wait for in-flight
        secondary batches to finish, hold it closed for the body (a graph
        patch or a partition commit), reopen on exit.  No-op while no
        secondaries are live (single-worker loops, inline pump, post-join
        drain) — the primary's own serving is naturally serialised."""
        if not any(t.is_alive() for t in self._secondaries):
            yield
            return
        with self._gate:
            self._gate_open = False
            while self._active_serves:
                self._gate.wait(0.1)
        try:
            yield
        finally:
            with self._gate:
                self._gate_open = True
                self._gate.notify_all()

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self._pump_once(wait_s=self.cfg.batch_wait_s,
                                allow_trigger=True)
                self._worker_error = None   # healthy round: blip recovered
            except BaseException as exc:
                # a dead worker would silently wedge every outstanding
                # ticket; log, remember for stop() (cleared again by the
                # next healthy round, so only a *current* fault surfaces
                # there), and keep serving — the backoff stops a
                # persistent fault from spinning hot
                self._worker_error = exc
                log.exception("serve worker round failed")
                time.sleep(self.cfg.batch_wait_s)
        self._finish_inflight()

    # -- one scheduling round -------------------------------------------------
    def pump(self, wait_s: float = 0.0) -> int:
        """Inline drive: one scheduling round on the calling thread.
        Returns the number of requests served this round."""
        return self._pump_once(wait_s=wait_s, allow_trigger=True)

    def _pump_once(self, wait_s: float, allow_trigger: bool) -> int:
        if self._replication is not None:
            # liveness beacon; silently lost from a stale epoch or across a
            # partition, which is what starts the coordinator's failover clock
            self._replication.heartbeat(self._epoch, self._applied_seq,
                                        int(self.g.version))
        self._commit_if_done()
        if self._pending is None and not self._zombies_active():
            self._apply_ingest()
        batch = self.requests.take_batch(self.cfg.micro_batch, timeout=wait_s)
        if batch:
            self._serve_batch(batch)
            if allow_trigger:
                self._maybe_trigger()
        if self._brownout is not None:
            # one controller window per elapsed window_s: reads the live
            # per-class latency quantiles, moves the queue's shed level
            self._brownout.maybe_tick()
        self._commit_if_done()
        if (self._snapshotter is not None
                and self.cfg.snapshot_every_s is not None
                and self._pending is None
                and not self._zombies_active()
                and time.monotonic() - self._last_snapshot_t
                >= self.cfg.snapshot_every_s):
            self.snapshot(sync=False)
        return len(batch)

    def _serve_batch(self, batch: List[ServeTicket],
                     worker_id: int = 0) -> None:
        overlapped = (self._inflight is not None
                      and not self._invocation_done.is_set())
        queries = [t.query for t in batch]
        part = self.ot.part  # one read: stable for the whole micro-batch
        batch_span = NOOP_SPAN
        if self._obs_on:
            # one drain→enumerate→reply span per micro-batch, joined to the
            # first sampled ticket's trace (a per-ticket span here would tax
            # the hot path ~2x; every sampled request still closes its own
            # admission-opened "request" span with the serve outcome)
            for t in batch:
                if t.trace.sampled:
                    batch_span = self.obs.tracer.start(
                        "request.batch", t.trace, worker_id=worker_id,
                        batch_size=len(batch),
                        queue_wait_s=(time.perf_counter() - t.submitted_s))
                    break
        t0 = time.perf_counter()
        enum_stats: Dict[str, int] = {}
        results = self.executor.enumerate_paths_many(
            queries, max_results=self.cfg.max_results_per_query, part=part,
            stats=enum_stats)
        dt = time.perf_counter() - t0
        batch_span.end(enum_sweeps=enum_stats.get("enum_sweeps", 0),
                       frontier_rows=enum_stats.get("frontier_rows", 0))
        for ticket, (paths, crossings) in zip(batch, results):
            ticket.complete(paths, crossings)
        if self._ctl_registry is not None:
            # per-class latency histograms: what the brownout controller's
            # windowed quantile estimator reads each controller window
            for t in batch:
                h = self._lat_hists.get(t.cls)
                if h is None:
                    h = self._lat_hists[t.cls] = self._ctl_registry.histogram(
                        "request_latency_s", cls=t.cls)
                h.observe(t.latency_s)
        self.requests.record_service_time(dt / len(batch))
        self.metrics.record_batch(
            [t.latency_s for t in batch], [t.ipt for t in batch], overlapped,
            enum_sweeps=enum_stats.get("enum_sweeps", 0),
            frontier_rows=enum_stats.get("frontier_rows", 0),
            worker_id=worker_id)
        with self._observe_lock:
            self.ot.observe(queries)
            # one snapshot per batch (O(#distinct queries)); admission reads
            # it lock-free via atomic rebind
            self._adm_freqs = self.ot.sketch.frequencies(
                self.ot.policy.min_freq)
            self._requests_since_invocation += len(batch)
            mean_ipt = float(np.mean([t.ipt for t in batch]))
            self._ipt_ewma = (mean_ipt if self._ipt_ewma is None
                              else 0.8 * self._ipt_ewma + 0.2 * mean_ipt)

    # -- invocation scheduling ------------------------------------------------
    def _serve_pressure(self) -> float:
        """The loop's [0, 1] overload signal (``serve.control``): queue
        fullness + brownout shed depth + invocation wall cost relative to
        the watchdog budget."""
        ctl = self.cfg.control
        depth_frac = self.requests.depth() / max(self.requests.max_depth, 1)
        shed_frac = (self.requests.shed_level
                     / max(self.requests.max_shed_level, 1))
        inv_frac = 0.0
        if self.cfg.invocation_timeout_s:
            inv_frac = min(
                1.0, self._inv_wall_ewma / self.cfg.invocation_timeout_s)
        return serve_pressure(depth_frac, shed_frac, inv_frac, ctl)

    def _maybe_trigger(self) -> None:
        pressure = (self._serve_pressure()
                    if self.cfg.control is not None else None)
        with self._observe_lock:
            # one tick per micro-batch; the sketch is concurrently written
            # by secondary workers' observe()
            reason = self.ot.poll(self._ipt_ewma, pressure=pressure)
        if reason is None or self._pending is not None:
            return
        if self._zombies_active():
            # an abandoned run is still reading the graph; starting another
            # enhancement (or mutating) under it is not safe — keep serving
            return
        if time.monotonic() < self._backoff_until:
            return  # abort-and-retry backoff after a failed invocation
        if self.ot.invocations == 0:
            if self.metrics.completed < self.cfg.first_invocation_after:
                return
        elif (self._requests_since_invocation
                < self.cfg.min_requests_between_invocations):
            return
        inv_root = NOOP_SPAN
        if self._obs_on:
            # invocations are rare and load-bearing: always sampled
            ctx = self.obs.tracer.new_trace(force=True)
            inv_root = self.obs.tracer.start(
                "invocation", ctx, reason=str(reason),
                overlapped=self.cfg.overlap_invocations, epoch=self._epoch)
            self._invocation_ctx = inv_root.context()
            self._invocation_span = inv_root
            # field/swap/redeal spans inside Taper join this trace
            self.ot.taper.trace_ctx = self._invocation_ctx
        with self._inv_span("invocation.snapshot"):
            with self._observe_lock:
                # the invocation snapshot reads the sketch/workload state
                pending = self.ot.begin_invocation(reason)
        if pending is None:
            inv_root.end(skipped=True)
            self._clear_invocation_trace()
            return
        self._pending = pending
        if self.cfg.overlap_invocations:
            self._invocation_done = threading.Event()
            self._abort_flag = threading.Event()
            self._invocation_error = None   # only the latest run's outcome
            self._invocation_t0 = time.perf_counter()
            self._inflight = threading.Thread(
                target=self._invocation_main,
                args=(pending, self._abort_flag, self._invocation_done),
                name="serve-invocation", daemon=True)
            self._inflight.start()
        else:
            t0 = time.perf_counter()
            try:
                if self._faults is not None:
                    self._faults.fire(SITE_INVOCATION)
                self.ot.run_invocation(pending)
            except BaseException as exc:
                self.metrics.record_invocation_failure()
                self._note_invocation_failure()
                inv_root.end(error=repr(exc))
                self._clear_invocation_trace()
                raise
            finally:
                # a failed run must not leave the loop looking mid-flight
                # (that would disable ingest and all future invocations);
                # the exception still propagates — to the inline caller, or
                # to _run's guard in threaded mode
                self._pending = None
            wall = time.perf_counter() - t0
            if not self._fenced_commit_guard():
                # deposed primary: the enhancement ran but its result may
                # not become durable or visible — drop it on the floor
                self._requests_since_invocation = 0
                inv_root.end(fenced=True)
                self._clear_invocation_trace()
                return
            with self._inv_span("invocation.commit"):
                with self._quiesced():
                    self.ot.commit_invocation(pending)
            self.metrics.record_invocation(wall, overlapped=False)
            self._inv_wall_ewma = 0.7 * self._inv_wall_ewma + 0.3 * wall
            self._requests_since_invocation = 0
            self._note_invocation_success()
            self._warm_devices()
            self._publish_commit()
            inv_root.end(committed=True, wall_s=wall)
            self._clear_invocation_trace()
            if self._snapshotter is not None and self.cfg.snapshot_on_commit:
                self.snapshot(sync=False)

    def _invocation_main(self, pending: PendingInvocation,
                         abort: threading.Event,
                         done: threading.Event) -> None:
        try:
            if self._faults is not None:
                self._faults.fire(SITE_INVOCATION)
            if abort.is_set():
                raise InvocationAborted("aborted before start")
            self.ot.run_invocation(pending, should_abort=abort.is_set)
        except InvocationAborted:
            # the watchdog already did the bookkeeping when it abandoned us;
            # exiting promptly is this thread's whole job now
            log.info("abandoned invocation run exited cooperatively")
        except BaseException as exc:  # surfaced by stop() if still latest
            if not abort.is_set():
                self._invocation_error = exc
                self.metrics.record_invocation_failure()
                log.exception("overlapped TAPER invocation failed")
        finally:
            done.set()

    def _commit_if_done(self) -> None:
        if self._inflight is None:
            return
        if not self._invocation_done.is_set():
            self._check_watchdog()
            return
        self._inflight.join()
        wall = time.perf_counter() - self._invocation_t0
        committed = False
        fenced = False
        if self._pending is not None and self._pending.report is not None:
            if self._fenced_commit_guard():
                # quiesce only for the pointer swap: secondaries finish
                # their in-flight batch, the commit rebinds ot.part (plus
                # the shard re-deal bookkeeping), the gate reopens
                with self._inv_span("invocation.commit"):
                    with self._quiesced():
                        self.ot.commit_invocation(self._pending)
                self.metrics.record_invocation(wall, overlapped=True)
                self._inv_wall_ewma = 0.7 * self._inv_wall_ewma + 0.3 * wall
                committed = True
            else:
                fenced = True
        self._pending = None
        self._inflight = None
        self._requests_since_invocation = 0
        if committed:
            self._note_invocation_success()
            # the commit may have re-dealt the shard map along the enhanced
            # partition (shard_map_source="partition"); re-pack and upload
            # now, on the worker between batches, so the next overlapped
            # invocation starts from a warm re-dealt layout
            self._warm_devices()
            self._publish_commit()
            self._invocation_span.end(committed=True, wall_s=wall)
            self._clear_invocation_trace()
            if self._snapshotter is not None and self.cfg.snapshot_on_commit:
                self.snapshot(sync=False)
        else:
            self._invocation_span.end(
                committed=False, fenced=fenced,
                error=("" if self._invocation_error is None
                       else repr(self._invocation_error)))
            self._clear_invocation_trace()
            if not fenced:
                # a fenced commit is the fence working, not a device fault —
                # it must not walk the backend ladder
                self._note_invocation_failure()

    def _check_watchdog(self) -> None:
        """Abort-and-abandon an overlapped run that blew its timeout.

        The run is cancelled cooperatively (``InvocationAborted`` at the
        next iteration boundary) and moved to the zombie list; serving
        continues immediately on the old partition, while ingest and new
        invocations wait for the zombie to actually exit."""
        timeout = self.cfg.invocation_timeout_s
        if timeout is None or self._inflight is None:
            return
        if time.perf_counter() - self._invocation_t0 < timeout:
            return
        self._abort_flag.set()
        self._abandoned.append(self._inflight)
        err = TimeoutError(
            f"invocation exceeded watchdog timeout ({timeout:g}s); "
            "aborted and abandoned")
        log.warning(str(err))
        self._invocation_error = err
        self.metrics.record_watchdog_abort()
        self.metrics.record_invocation_failure()
        self.obs.recorder.record("watchdog_abort", timeout_s=float(timeout))
        self.obs.recorder.trigger("degradation:watchdog_abort")
        self._invocation_span.end(committed=False, aborted=True,
                                  error=str(err))
        self._clear_invocation_trace()
        self._pending = None
        self._inflight = None
        # fresh event: the zombie holds (and will set) the old one
        self._invocation_done = threading.Event()
        self._note_invocation_failure()

    def _zombies_active(self) -> bool:
        if self._abandoned:
            self._abandoned = [t for t in self._abandoned if t.is_alive()]
        return bool(self._abandoned)

    # -- degradation ladder ---------------------------------------------------
    def _note_invocation_failure(self) -> None:
        # the consecutive count only drives the retry backoff now; the
        # demotion decision belongs to the breaker (rate-over-window with
        # a consecutive-tail clause — see ServeLoopConfig.control)
        self._consec_invocation_failures += 1
        backoff = (self.cfg.invocation_retry_backoff_s
                   * 2 ** (self._consec_invocation_failures - 1))
        self._backoff_until = time.monotonic() + backoff
        if self._backend_breaker.record_failure():
            self._fall_back_backend()
            # each rung starts with a clean window: failures that demoted
            # off the old rung are not evidence against the new one
            self._backend_breaker.reset()

    def _fall_back_backend(self) -> None:
        cur = self.ot.taper.config.field_backend
        try:
            i = FIELD_BACKEND_LADDER.index(cur)
        except ValueError:
            return
        if i + 1 >= len(FIELD_BACKEND_LADDER):
            return  # already at the bottom rung; keep retrying with backoff
        nxt = FIELD_BACKEND_LADDER[i + 1]
        self.ot.taper.set_field_backend(nxt)
        self.metrics.record_backend_fallback()
        self._consec_invocation_failures = 0
        self._healthy_since_fallback = 0
        self.obs.recorder.record("backend_fallback", from_backend=cur,
                                 to_backend=nxt)
        self.obs.recorder.trigger("degradation:backend_fallback")
        log.warning("field backend degraded %s -> %s after repeated "
                    "invocation failures", cur, nxt)

    def _note_invocation_success(self) -> None:
        self._consec_invocation_failures = 0
        self._backoff_until = 0.0
        self._backend_breaker.record_success()
        cur = self.ot.taper.config.field_backend
        if cur == self._base_backend:
            self._probe_after = self.cfg.backend_probe_after
            return
        self._healthy_since_fallback += 1
        if self._healthy_since_fallback < self._probe_after:
            return
        i = FIELD_BACKEND_LADDER.index(cur)
        try:
            base_i = FIELD_BACKEND_LADDER.index(self._base_backend)
        except ValueError:
            base_i = 0
        if i <= base_i:
            return
        up = FIELD_BACKEND_LADDER[i - 1]
        self.ot.taper.set_field_backend(up)
        self.metrics.record_backend_recovery()
        self.obs.recorder.record("backend_recovery", from_backend=cur,
                                 to_backend=up)
        # a failed probe falls straight back down (the ladder counters
        # re-engage); doubling the dwell makes a flapping device converge
        # onto its stable rung instead of oscillating
        self._probe_after *= 2
        self._healthy_since_fallback = 0
        log.info("field backend probing recovery %s -> %s", cur, up)

    def _finish_inflight(self) -> None:
        if self._inflight is not None:
            self._invocation_done.wait()
            self._commit_if_done()
        for t in self._abandoned:
            # abort flag is set; the zombie exits at its next iteration
            # boundary — wait it out so shutdown leaves no thread behind
            t.join()
        self._abandoned = []

    # -- ingest ---------------------------------------------------------------
    def _apply_ingest(self) -> None:
        if self.ingest.depth() == 0:
            return
        with self._quiesced():
            self._apply_ingest_locked()

    def _apply_ingest_locked(self) -> None:
        applied = 0
        for merged, members in self.ingest.drain_groups():
            ing_ctx = (self.obs.tracer.new_trace() if self._obs_on
                       else NOOP_TRACE)
            ing_span = (self.obs.tracer.start("ingest.group", ing_ctx,
                                              members=len(members))
                        if ing_ctx.sampled else NOOP_SPAN)
            if self._replication is not None:
                # the fence is checked *before* the journal append: a
                # deposed or partitioned primary never writes divergent
                # records into the shared WAL, so its local state stays a
                # consistent stale prefix and rejoin is pure tail replay
                try:
                    self._replication.authorize(self._epoch, "ingest group")
                except FencedWrite as exc:
                    self._note_fenced(exc)
                    self.ingest.failed += len(members)
                    ing_span.end(fenced=True)
                    continue
            # WAL boundary: the group is journaled before it applies, and
            # its outcome (fold vs per-member fallback, member fates) right
            # after — replay reproduces the exact apply stream
            gseq = (self._journal.append_group(members)
                    if self._journal is not None else self._applied_seq + 1)
            flags = None
            try:
                if self._faults is not None:
                    self._faults.fire(SITE_INGEST_GROUP)
                self.ot.apply_mutations(merged)
                applied += 1
                mode = "merged"
            except (ValueError, InjectedFault):
                # a malformed producer batch (or injected poison) spoiled
                # the fold; apply the member batches individually so only
                # the bad one is lost (apply_mutations validates before
                # touching any state, so the failed fold left the graph
                # untouched)
                log.exception(
                    "coalesced ingest group failed; retrying "
                    "its %d member batches individually", len(members))
                mode, flags = "members", []
                for b in members:
                    try:
                        self.ot.apply_mutations(b)
                        applied += 1
                        flags.append(True)
                    except ValueError:
                        self.ingest.failed += 1
                        flags.append(False)
                        log.exception("dropping malformed ingest batch")
            if self._journal is not None:
                self._journal.append_outcome(
                    gseq, mode, flags if flags is not None
                    else [True] * len(members))
            self._applied_seq = gseq
            if self._replication is not None:
                try:
                    self._replication.publish_group(
                        self._epoch, gseq, members, mode,
                        flags if flags is not None else [True] * len(members),
                        int(self.g.version),
                        trace_id=(ing_ctx.trace_id if ing_ctx.sampled
                                  else None))
                except FencedWrite as exc:
                    # lost the lease between journal append and ship; the
                    # record is durable and followers pick it up from the
                    # journal tail, so only the push is skipped
                    self._note_fenced(exc)
            ing_span.end(seq=gseq, mode=mode)
        if applied:
            self._warm_devices()

    def _warm_devices(self) -> None:
        """Stream the freshly patched dirty shards onto the mesh now, off
        the invocation's critical path, so the next overlapped field
        evaluation starts from warm device buffers.  An upload failure is
        survivable — serving continues on the previous device buffers and
        the next field evaluation re-uploads lazily — but counts toward the
        degradation ladder like an invocation failure."""
        taper = self.ot.taper
        if taper.config.field_backend != "pallas_sharded":
            return
        try:
            with self._inv_span("invocation.shard_upload"):
                self._warm_devices_inner()
        except BaseException:
            self.metrics.record_upload_failure()
            self._note_invocation_failure()
            log.exception("shard upload failed; serving continues on the "
                          "previous device state")

    def _warm_devices_inner(self) -> None:
        if self._faults is not None:
            self._faults.fire(SITE_SHARD_UPLOAD)
        import jax

        from repro.core.visitor import _sharded_device_arrays

        pre = self.ot.taper._pre
        mesh = pre.get("_mesh")
        n_shards = (int(mesh.shape["model"]) if mesh is not None
                    else len(jax.devices()))
        token, order = pre.get("_shard_order") or ("stripe", None)
        sp = self.g.vm_packing_sharded(
            n_shards, cnt=self.g.cached_neighbor_label_counts(),
            order=order, order_token=token)
        _sharded_device_arrays(sp, pre)
