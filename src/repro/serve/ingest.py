"""Mutation ingest: bounded queue of :class:`MutationBatch` with coalescing.

The ingest path of the serving subsystem: producers submit topology deltas
(:meth:`IngestQueue.submit`, bounded with the same backpressure contract as
the request queue) and the serving loop drains them *between* TAPER
invocations — the graph must stay immutable while a field evaluation is in
flight on another thread — applying each through
``LabelledGraph.apply_mutations`` so every derived cache (CSR arrays,
reverse index, neighbour-label counts, per-shard ``vm_packing_sharded``
entries) is merge-patched rather than rebuilt, and the next sharded field
evaluation re-uploads only the dirty shards.

:func:`coalesce_mutations` folds a backlog of batches into (usually) one
equivalent batch before applying, so a burst of small deltas costs one
merge-patch pass instead of many.  The fold is *order-aware*: each edge's
final presence is decided by the last operation that names it, matching
the sequential apply semantics exactly ("removals before additions" holds
only *within* one batch).  One interaction cannot be expressed in a single
batch — an edge added *after* one of its endpoints was removed by an
earlier batch (``apply_mutations`` drops additions touching a same-batch
removed vertex) — so the fold splits into a new group at that point and
returns more than one batch, applied in order.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Sequence, Tuple, Union

import numpy as np

from repro.graphs.graph import MutationBatch
from repro.serve.queueing import Rejection


def _normalized_edges(edges) -> List[Tuple[int, int]]:
    arr = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    lo = np.minimum(arr[:, 0], arr[:, 1])
    hi = np.maximum(arr[:, 0], arr[:, 1])
    return list(zip(lo.tolist(), hi.tolist()))


class _Group:
    """One coalesced batch under construction."""

    def __init__(self):
        self.labels: List[int] = []
        self.edge_ops: Dict[Tuple[int, int], str] = {}  # key -> add|remove
        self.removed_vs: set = set()
        self.relabel: Dict[int, int] = {}
        self.members: List[MutationBatch] = []

    def conflicts(self, batch: MutationBatch) -> bool:
        """True when folding ``batch`` in would change semantics: it re-adds
        an edge incident to a vertex an earlier batch removed."""
        if not self.removed_vs or not len(batch.add_edges):
            return False
        return any(a in self.removed_vs or b in self.removed_vs
                   for a, b in _normalized_edges(batch.add_edges))

    def fold(self, batch: MutationBatch) -> None:
        self.labels.extend(int(x) for x in batch.add_vertex_labels)
        # within one source batch removals precede additions, matching
        # apply_mutations; across batches the last op per edge key wins
        for key in _normalized_edges(batch.remove_edges):
            self.edge_ops[key] = "remove"
        for key in _normalized_edges(batch.add_edges):
            self.edge_ops[key] = "add"
        self.removed_vs.update(int(v) for v in batch.remove_vertices)
        for v, lab in np.asarray(
                batch.relabel, dtype=np.int64).reshape(-1, 2).tolist():
            self.relabel[int(v)] = int(lab)
        self.members.append(batch)

    def to_batch(self) -> MutationBatch:
        add = [k for k, op in self.edge_ops.items() if op == "add"]
        rem = [k for k, op in self.edge_ops.items() if op == "remove"]
        return MutationBatch(
            add_vertex_labels=self.labels,
            add_edges=np.asarray(add, np.int64).reshape(-1, 2),
            remove_edges=np.asarray(rem, np.int64).reshape(-1, 2),
            remove_vertices=sorted(self.removed_vs),
            relabel=[(v, l) for v, l in self.relabel.items()],
        )


def coalesce_groups(
    batches: Sequence[MutationBatch],
) -> List[Tuple[MutationBatch, List[MutationBatch]]]:
    """Fold pending batches into the fewest equivalent batches (see module
    docstring), returning each fold with its original member batches —
    consumers that hit a validation error on a fold can fall back to the
    members individually, so one malformed producer batch never discards
    the valid batches coalesced with it."""
    groups: List[_Group] = []
    for b in batches:
        if b.is_empty:
            continue
        if not groups or groups[-1].conflicts(b):
            groups.append(_Group())
        groups[-1].fold(b)
    return [(grp.to_batch(), grp.members) for grp in groups]


def coalesce_mutations(
    batches: Sequence[MutationBatch],
) -> List[MutationBatch]:
    """Fold pending batches into the fewest equivalent batches (see module
    docstring).  Applying the result in order to a graph produces arrays
    bit-identical to applying the originals in order."""
    return [merged for merged, _ in coalesce_groups(batches)]


class IngestQueue:
    """Thread-safe bounded FIFO of :class:`MutationBatch`."""

    def __init__(self, max_depth: int = 64):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = int(max_depth)
        self._items: List[MutationBatch] = []
        self._lock = threading.Lock()
        self.submitted = 0
        self.rejected = 0
        #: malformed batches dropped at apply time (serving loop accounting)
        self.failed = 0
        self.applied_batches = 0
        self.coalesced_from = 0

    def submit(self, batch: MutationBatch) -> Union[bool, Rejection]:
        """Queue one mutation batch, or reject with a retry hint when the
        backlog (typically: a long-running overlapped invocation is
        deferring ingest) is at capacity."""
        with self._lock:
            depth = len(self._items)
            if depth >= self.max_depth:
                self.rejected += 1
                return Rejection(retry_after_s=0.01 * depth,
                                 queue_depth=depth, reason="ingest_full")
            self._items.append(batch)
            self.submitted += 1
            return True

    def drain(self) -> List[MutationBatch]:
        """Remove everything pending and return it coalesced (FIFO order)."""
        return [merged for merged, _ in self.drain_groups()]

    def drain_groups(self) -> List[Tuple[MutationBatch, List[MutationBatch]]]:
        """Like :meth:`drain`, but each coalesced batch comes with its
        original member batches (the serving loop's fallback unit when a
        fold fails validation)."""
        with self._lock:
            items = self._items
            self._items = []
        out = coalesce_groups(items)
        self.coalesced_from += len(items)
        self.applied_batches += len(out)
        return out

    def depth(self) -> int:
        with self._lock:
            return len(self._items)
