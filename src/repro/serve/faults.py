"""Fault injection for the serving loop.

A :class:`FaultInjector` is handed to ``ServingLoop`` via
``ServeLoopConfig.faults``; the loop calls :meth:`FaultInjector.fire` at
named *sites* on its hot paths and the injector either does nothing (the
site is unarmed) or raises / stalls per the armed :class:`FaultSpec`.  Sites
the loop exposes:

* ``invocation``   — start of the TAPER invocation thread body (kills the
  enhancement mid-run; drives the watchdog + backend-fallback ladder).
* ``shard_upload`` — inside ``_warm_devices`` before the sharded packing is
  pushed to devices (fails the device upload path).
* ``ingest_group`` — before a coalesced mutation group is applied (poisons
  the merged batch; exercises the per-member fallback).

Replication (``serve.replication``) adds transport and replica sites.  The
``ShipChannel`` fires transport sites per frame and interprets the armed
spec as a network behaviour instead of an exception: ``ship_drop`` loses
the frame, ``ship_delay`` holds it back a poll round (late, out-of-order
delivery), ``ship_reorder`` swaps it with the next frame.  Each may be
armed bare (every channel) or qualified per follower as
``"<site>:<name>"``; ``link_partition`` (checked via :meth:`armed`, not
consumed) blackholes a channel entirely.  Replica sites fault the follower
itself: ``replica_apply`` (before a shipped frame applies — ``raise``
crashes the replica, ``stall`` lags it) and ``replica_serve`` (before a
read executes — ``stall`` trips the router's deadline hedging, ``raise``
fails the read over to another replica).

Snapshot corruption has no hook site — it attacks data at rest — so it is a
plain function, :func:`corrupt_latest_snapshot`, flipping bytes in the
newest snapshot's ``arrays.npz`` to exercise the checksum-verified
fall-back-to-older-snapshot path in ``serve.snapshot``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Type

from repro.utils import get_logger

log = get_logger("serve.faults")

#: canonical site names (the loop fires these; tests arm them)
SITE_INVOCATION = "invocation"
SITE_SHARD_UPLOAD = "shard_upload"
SITE_INGEST_GROUP = "ingest_group"
#: replication transport sites (fired per frame by ``ShipChannel``; may be
#: qualified per follower as ``f"{site}:{name}"``)
SITE_SHIP_DROP = "ship_drop"
SITE_SHIP_DELAY = "ship_delay"
SITE_SHIP_REORDER = "ship_reorder"
#: persistent link state (checked, not consumed): blackholes a channel
SITE_LINK_PARTITION = "link_partition"
#: follower replica sites: crash/stall the apply path, fail/stall reads
SITE_REPLICA_APPLY = "replica_apply"
SITE_REPLICA_SERVE = "replica_serve"

#: every site the serving stack fires.  ``arm()`` validates against this
#: registry: a typo'd site would otherwise never fire and the test that
#: armed it would pass vacuously.
KNOWN_SITES = frozenset({
    SITE_INVOCATION, SITE_SHARD_UPLOAD, SITE_INGEST_GROUP,
    SITE_SHIP_DROP, SITE_SHIP_DELAY, SITE_SHIP_REORDER,
    SITE_LINK_PARTITION, SITE_REPLICA_APPLY, SITE_REPLICA_SERVE,
})


class InjectedFault(RuntimeError):
    """Raised by an armed ``mode="raise"`` fault site."""


@dataclass
class FaultSpec:
    """One armed fault.

    ``times`` bounds how often the site fires (``<= 0`` = every time —
    a *permanent* fault, e.g. for degraded-throughput floors).  ``mode``
    is ``"raise"`` (raise ``exc``) or ``"stall"`` (sleep ``delay_s``,
    e.g. to trip the invocation watchdog)."""

    mode: str = "raise"              # "raise" | "stall"
    times: int = 1
    delay_s: float = 0.0
    exc: Type[BaseException] = InjectedFault

    def __post_init__(self):
        if self.mode not in ("raise", "stall"):
            raise ValueError(f"unknown fault mode {self.mode!r}")


class FaultInjector:
    """Thread-safe registry of armed fault sites."""

    def __init__(self):
        self._lock = threading.Lock()
        self._armed: Dict[str, FaultSpec] = {}
        self.fired: Dict[str, int] = {}
        #: optional flight recorder (wired by the serving loop / cluster):
        #: every armed firing records a ``fault_fired`` event and triggers
        #: an auto-dump, so the ring around the fault is preserved
        self.recorder = None

    def arm(self, site: str, mode: str = "raise", times: int = 1,
            delay_s: float = 0.0,
            exc: Type[BaseException] = InjectedFault) -> None:
        """Arm ``site`` to fault on its next ``times`` firings.

        The site's bare name (before any ``:<follower>`` qualifier) must
        be in :data:`KNOWN_SITES` — a typo'd site never fires, so the
        test that armed it would pass vacuously."""
        base = site.split(":", 1)[0]
        if base not in KNOWN_SITES:
            raise ValueError(
                f"unknown fault site {site!r}; valid sites: "
                f"{', '.join(sorted(KNOWN_SITES))}")
        spec = FaultSpec(mode=mode, times=times, delay_s=delay_s, exc=exc)
        with self._lock:
            self._armed[site] = spec

    def disarm(self, site: Optional[str] = None) -> None:
        """Disarm one site, or every site when ``site`` is None."""
        with self._lock:
            if site is None:
                self._armed.clear()
            else:
                self._armed.pop(site, None)

    def fire(self, site: str) -> None:
        """Called by the loop at a fault site.  No-op unless armed."""
        with self._lock:
            spec = self._armed.get(site)
            if spec is None:
                return
            if spec.times > 0:
                spec.times -= 1
                if spec.times == 0:
                    del self._armed[site]
            self.fired[site] = self.fired.get(site, 0) + 1
        log.info("firing injected fault at %s (%s)", site, spec.mode)
        if self.recorder is not None:
            self.recorder.record("fault_fired", site=site, mode=spec.mode)
            self.recorder.trigger(f"fault:{site}")
        if spec.mode == "stall":
            time.sleep(spec.delay_s)
        else:
            raise spec.exc(f"injected fault at {site}")

    def armed(self, site: str) -> bool:
        """True while ``site`` is armed, without consuming a firing — for
        persistent *state* faults (``link_partition``) that gate behaviour
        for as long as they stay armed rather than firing N times."""
        with self._lock:
            return site in self._armed

    def fired_total(self) -> int:
        with self._lock:
            return sum(self.fired.values())


def corrupt_latest_snapshot(directory) -> Path:
    """Flip bytes in the middle of the newest snapshot's ``arrays.npz``
    (data-at-rest corruption).  Returns the damaged file's path; raises
    ``FileNotFoundError`` when no snapshot exists."""
    from repro.serve.snapshot import SNAP_PREFIX

    directory = Path(directory)
    snaps = sorted(p for p in directory.glob(SNAP_PREFIX + "*")
                   if (p / "arrays.npz").exists())
    if not snaps:
        raise FileNotFoundError(f"no snapshot to corrupt under {directory}")
    target = snaps[-1] / "arrays.npz"
    blob = bytearray(target.read_bytes())
    mid = len(blob) // 2
    for off in range(mid, min(mid + 16, len(blob))):
        blob[off] ^= 0xFF
    target.write_bytes(bytes(blob))
    log.info("corrupted %s (%d bytes flipped mid-file)", target,
             min(16, len(blob) - mid))
    return target
