"""Atomic, versioned, mesh-agnostic checkpointing.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json
Writes go to a temp directory then os.replace (atomic on POSIX), so a crash
mid-save never corrupts the latest checkpoint.  Arrays are stored unsharded
(device_get) with their pytree structure in the manifest — restoring onto a
*different* mesh is just device_put with the new shardings (elastic scaling,
see repro.train.elastic).  An optional background thread makes saves
non-blocking (async checkpointing).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.utils import get_logger

log = get_logger("train.checkpoint")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(k) for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def atomic_dir_publish(parent: Path, final_name: str, writer) -> Path:
    """Write a directory atomically: ``writer(tmp_path)`` populates a fresh
    temp dir under ``parent``, which is then ``os.replace``d to
    ``parent/final_name`` — a crash mid-write never corrupts (or even
    reveals) a partially written directory.  Replaces an existing
    ``final_name``.  Shared by checkpointing and the serving snapshotter."""
    parent = Path(parent)
    final = parent / final_name
    tmp = Path(tempfile.mkdtemp(dir=parent, prefix=".tmp_"))
    try:
        writer(tmp)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        # serializes join-then-spawn: without it two concurrent save()
        # callers can both pass the join, overwrite each other's handle and
        # interleave their writes with keep-pruning
        self._save_lock = threading.Lock()

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             metadata: Optional[Dict] = None) -> None:
        """state: pytree (e.g. {"params": ..., "opt_state": ...})."""
        host_state = jax.device_get(state)
        if self.async_save:
            with self._save_lock:
                if self._thread is not None:
                    self._thread.join()
                self._thread = threading.Thread(
                    target=self._write,
                    args=(step, host_state, metadata or {}), daemon=True)
                self._thread.start()
        else:
            with self._save_lock:
                self._write(step, host_state, metadata or {})

    def wait(self) -> None:
        with self._save_lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()

    def close(self) -> None:
        """Join any in-flight async save; the manager stays usable (a later
        ``save`` simply spawns a fresh writer)."""
        self.wait()

    def _write(self, step: int, host_state, metadata: Dict) -> None:
        t0 = time.time()
        keys, vals, _ = _flatten_with_paths(host_state)

        def writer(tmp: Path) -> None:
            np.savez(tmp / "arrays.npz",
                     **{f"a{i}": np.asarray(v) for i, v in enumerate(vals)})
            manifest = {
                "step": step,
                "keys": keys,
                "time": time.time(),
                "metadata": metadata,
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))

        atomic_dir_publish(self.dir, f"step_{step:010d}", writer)
        self._gc()
        log.info("checkpoint step %d saved in %.2fs", step, time.time() - t0)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any], step: Optional[int] = None,
                shardings=None) -> Dict[str, Any]:
        """Restore into the structure of ``like`` (pytree of arrays or
        ShapeDtypeStructs).  With ``shardings`` (matching pytree), arrays are
        device_put with them — this is the elastic-resharding path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:010d}"
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "arrays.npz")
        vals = [data[f"a{i}"] for i in range(len(manifest["keys"]))]

        keys_like, like_vals, treedef = _flatten_with_paths(like)
        if keys_like != manifest["keys"]:
            raise ValueError(
                "checkpoint structure mismatch:\n"
                f"  ckpt: {manifest['keys'][:5]}...\n  like: {keys_like[:5]}...")
        if shardings is not None:
            shard_flat = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
            vals = [jax.device_put(v, s) for v, s in zip(vals, shard_flat)]
        else:
            vals = [jax.numpy.asarray(v) for v in vals]
        return jax.tree.unflatten(treedef, vals)

    def metadata(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        path = self.dir / f"step_{step:010d}"
        return json.loads((path / "manifest.json").read_text())["metadata"]
