"""Fault-tolerant training loop.

Production behaviours implemented (and covered by tests/test_trainer.py):

* periodic atomic checkpoints + automatic resume-from-latest (bitwise
  identical to an uninterrupted run — tested);
* failure injection (``fail_at_step``) to exercise the crash/restart path;
* straggler detection: per-step wall-time EWMA + spike counter.  On real
  multi-host deployments the watchdog triggers the documented mitigation
  (synchronous backup step / hot-spare swap); here the detection machinery
  itself is implemented and tested with injected delays;
* optional int8 gradient compression with error feedback (DP wire-byte
  reduction; convergence tested against the uncompressed run).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import compress_grads, init_residuals
from repro.train.checkpoint import CheckpointManager
from repro.utils import get_logger

log = get_logger("train.trainer")


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = False
    log_every: int = 10
    # fault tolerance / chaos
    fail_at_step: Optional[int] = None          # raise to simulate a crash
    # straggler watchdog
    straggler_factor: float = 3.0               # step > factor * EWMA => flag
    straggler_ewma: float = 0.9
    # gradient compression
    compress_grads: bool = False


@dataclass
class StragglerStats:
    ewma_s: float = 0.0
    flagged_steps: List[int] = field(default_factory=list)
    warmup: int = 2   # first steps include jit compile — never representative

    def observe(self, step: int, dt: float, factor: float, decay: float) -> bool:
        if self.warmup > 0:
            self.warmup -= 1
            return False
        if self.ewma_s == 0.0:
            self.ewma_s = dt
            return False
        slow = dt > factor * self.ewma_s
        if slow:
            self.flagged_steps.append(step)
        else:
            self.ewma_s = decay * self.ewma_s + (1 - decay) * dt
        return slow


class Trainer:
    """Generic loop over (params, opt_state) with a jitted step fn.

    ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``.
    When compression is on, the loop uses ``grad_step_fn(params, batch) ->
    (grads, metrics)`` + ``apply_fn(params, grads, opt_state)`` so the
    compressor sits on the gradient path.
    """

    def __init__(
        self,
        config: TrainerConfig,
        step_fn: Callable,
        params,
        opt_state,
        data: Iterator,
        grad_step_fn: Optional[Callable] = None,
        apply_fn: Optional[Callable] = None,
        step_hook: Optional[Callable[[int], None]] = None,
    ):
        self.cfg = config
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.grad_step_fn = grad_step_fn
        self.apply_fn = apply_fn
        self.step_hook = step_hook
        self.ckpt = CheckpointManager(
            config.checkpoint_dir, keep=config.keep_checkpoints,
            async_save=config.async_checkpoint)
        self.stragglers = StragglerStats()
        self.step = 0
        self.metrics_history: List[Dict] = []
        self._residuals = None

    # -- fault tolerance ---------------------------------------------------
    def try_resume(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = self.ckpt.restore(
            {"params": self.params, "opt_state": self.opt_state}, step=latest)
        self.params = state["params"]
        self.opt_state = state["opt_state"]
        self.step = latest
        log.info("resumed from checkpoint step %d", latest)
        return True

    def _checkpoint(self) -> None:
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt_state": self.opt_state},
            metadata={"step": self.step},
        )

    # -- loop ------------------------------------------------------------------
    def run(self) -> Dict:
        cfg = self.cfg
        if cfg.compress_grads and self._residuals is None:
            self._residuals = init_residuals(self.params)
        while self.step < cfg.total_steps:
            batch = next(self.data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            if self.step_hook:
                self.step_hook(self.step)  # chaos/latency injection for tests
            if cfg.fail_at_step is not None and self.step == cfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {self.step}")

            if cfg.compress_grads:
                grads, metrics = self.grad_step_fn(self.params, batch)
                grads, self._residuals = compress_grads(grads, self._residuals)
                self.params, self.opt_state = self.apply_fn(
                    self.params, grads, self.opt_state)
            else:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
            jax.block_until_ready(self.params)
            dt = time.perf_counter() - t0
            self.step += 1

            slow = self.stragglers.observe(
                self.step, dt, cfg.straggler_factor, cfg.straggler_ewma)
            if slow:
                log.warning("straggler flagged at step %d (%.3fs vs EWMA %.3fs)",
                            self.step, dt, self.stragglers.ewma_s)
            self.metrics_history.append(
                {k: float(v) for k, v in metrics.items()} | {"step_time_s": dt})
            if self.step % cfg.log_every == 0:
                log.info("step %d: %s", self.step,
                         {k: round(float(v), 4) for k, v in metrics.items()})
            if self.step % cfg.checkpoint_every == 0:
                self._checkpoint()
        self.ckpt.wait()
        return {
            "final_step": self.step,
            "metrics": self.metrics_history,
            "stragglers": self.stragglers.flagged_steps,
        }
