"""Elastic scaling: reshard a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic (unsharded numpy + structure manifest), so
scaling from N to M chips is: build the new mesh, resolve shardings from the
same logical-axis rules, and ``restore(..., shardings=new)``.  The logical
rules make this a pure re-layout — no model or optimizer surgery.

``plan_reshard`` additionally reports the per-device byte movement the
re-layout implies (useful to budget the scale-up pause).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.distributed.sharding import LogicalAxisRules, rules_for, tree_shardings
from repro.train.checkpoint import CheckpointManager
from repro.utils import get_logger

log = get_logger("train.elastic")


def reshard_restore(
    ckpt: CheckpointManager,
    like,
    logical_tree,
    new_mesh,
    rules: Optional[LogicalAxisRules] = None,
    step: Optional[int] = None,
):
    """Restore a checkpoint onto ``new_mesh`` (different size/topology)."""
    shardings = tree_shardings(new_mesh, logical_tree, like, rules)
    return ckpt.restore(like, step=step, shardings=shardings)


def plan_reshard(like, logical_tree, old_mesh, new_mesh,
                 rules_old=None, rules_new=None) -> Dict[str, Any]:
    """Byte-movement estimate for an elastic transition."""
    rules_old = rules_old or rules_for(old_mesh)
    rules_new = rules_new or rules_for(new_mesh)
    total_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(like))
    old_chips = int(old_mesh.devices.size)
    new_chips = int(new_mesh.devices.size)
    return {
        "total_state_bytes": total_bytes,
        "old_chips": old_chips,
        "new_chips": new_chips,
        "bytes_per_new_chip": total_bytes / max(new_chips, 1),
        # worst case: every new chip pulls its full shard from elsewhere
        "est_transfer_bytes": total_bytes,
    }
