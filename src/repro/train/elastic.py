"""Elastic scaling: reshard a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic (unsharded numpy + structure manifest), so
scaling from N to M chips is: build the new mesh, resolve shardings from the
same logical-axis rules, and ``restore(..., shardings=new)``.  The logical
rules make this a pure re-layout — no model or optimizer surgery.

``plan_reshard`` additionally reports the per-device byte movement the
re-layout implies (useful to budget the scale-up pause).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.distributed.sharding import LogicalAxisRules, rules_for, tree_shardings
from repro.train.checkpoint import CheckpointManager
from repro.utils import get_logger

log = get_logger("train.elastic")


def reshard_restore(
    ckpt: CheckpointManager,
    like,
    logical_tree,
    new_mesh,
    rules: Optional[LogicalAxisRules] = None,
    step: Optional[int] = None,
):
    """Restore a checkpoint onto ``new_mesh`` (different size/topology)."""
    shardings = tree_shardings(new_mesh, logical_tree, like, rules)
    return ckpt.restore(like, step=step, shardings=shardings)


def movement_plan(total_state_bytes: int, old_chips: int, new_chips: int,
                  est_transfer_bytes: Optional[int] = None) -> Dict[str, Any]:
    """The reshard-plan dict shape shared by every elastic transition —
    training checkpoints (:func:`plan_reshard`) and serving snapshots
    (``repro.serve.snapshot.plan_elastic_restore``) report byte-movement
    budgets through the same keys so operator tooling reads one schema."""
    return {
        "total_state_bytes": int(total_state_bytes),
        "old_chips": int(old_chips),
        "new_chips": int(new_chips),
        "bytes_per_new_chip": total_state_bytes / max(new_chips, 1),
        # default worst case: every new chip pulls its full shard
        "est_transfer_bytes": int(
            total_state_bytes if est_transfer_bytes is None
            else est_transfer_bytes),
    }


def plan_reshard(like, logical_tree, old_mesh, new_mesh,
                 rules_old=None, rules_new=None) -> Dict[str, Any]:
    """Byte-movement estimate for an elastic transition."""
    rules_old = rules_old or rules_for(old_mesh)
    rules_new = rules_new or rules_for(new_mesh)
    total_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(like))
    return movement_plan(
        total_bytes, int(old_mesh.devices.size), int(new_mesh.devices.size))
