"""Wall-clock timing helpers (host-side; device work must be blocked first)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating named timer.

    >>> t = Timer()
    >>> with t.section("foo"):
    ...     pass
    >>> t.totals["foo"] >= 0
    True
    """

    totals: dict = field(default_factory=dict)
    counts: dict = field(default_factory=dict)

    def section(self, name: str):
        timer = self

        class _Ctx:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                dt = time.perf_counter() - self.t0
                timer.totals[name] = timer.totals.get(name, 0.0) + dt
                timer.counts[name] = timer.counts.get(name, 0) + 1
                return False

        return _Ctx()

    def summary(self) -> str:
        lines = []
        for name, tot in sorted(self.totals.items(), key=lambda kv: -kv[1]):
            n = self.counts[name]
            lines.append(f"{name:<32} total={tot:8.3f}s  n={n:<5d} mean={tot / n:8.4f}s")
        return "\n".join(lines)
