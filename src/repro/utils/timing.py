"""Wall-clock timing helpers (host-side; device work must be blocked first).

.. deprecated::
    ``Timer`` is now a thin shim over a private
    :class:`repro.obs.registry.Registry` histogram per section — the
    unified metrics registry is the system of record for timing data.
    Existing benchmark callers keep the ``section``/``totals``/``counts``/
    ``summary`` surface unchanged; new code should take a ``Registry``
    (or an ``Observability`` bundle) and call
    ``registry.histogram("...").observe(dt)`` directly.
"""
from __future__ import annotations

import time


class Timer:
    """Accumulating named timer (deprecated shim; see module doc).

    >>> t = Timer()
    >>> with t.section("foo"):
    ...     pass
    >>> t.totals["foo"] >= 0
    True
    """

    def __init__(self, registry=None):
        from repro.obs.registry import Registry

        self.registry = registry if registry is not None else Registry()

    def _hists(self):
        return [m for m in self.registry.metrics()
                if m.kind == "histogram" and m.name.startswith("timer_")]

    @property
    def totals(self) -> dict:
        return {m.name[len("timer_"):]: m.sum for m in self._hists()}

    @property
    def counts(self) -> dict:
        return {m.name[len("timer_"):]: m.count for m in self._hists()}

    def section(self, name: str):
        hist = self.registry.histogram(f"timer_{name}")

        class _Ctx:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                hist.observe(time.perf_counter() - self.t0)
                return False

        return _Ctx()

    def summary(self) -> str:
        lines = []
        counts = self.counts
        for name, tot in sorted(self.totals.items(), key=lambda kv: -kv[1]):
            n = counts[name]
            lines.append(f"{name:<32} total={tot:8.3f}s  n={n:<5d} mean={tot / n:8.4f}s")
        return "\n".join(lines)
