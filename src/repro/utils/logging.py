"""Structured logging for the repro framework."""
from __future__ import annotations

import logging
import os
import sys

_FORMAT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"
_configured = False


def _configure_root() -> None:
    global _configured
    if _configured:
        return
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
    root = logging.getLogger("repro")
    root.addHandler(handler)
    root.setLevel(os.environ.get("REPRO_LOG_LEVEL", "INFO").upper())
    root.propagate = False
    _configured = True


def get_logger(name: str) -> logging.Logger:
    _configure_root()
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)


def json_default(obj):
    """``json.dumps(..., default=json_default)`` helper that folds numpy
    scalars/arrays (and anything else with ``item``/``tolist``) into plain
    Python values; unknown objects degrade to ``str`` rather than raising
    mid-export."""
    tolist = getattr(obj, "tolist", None)
    if callable(tolist):
        return tolist()
    item = getattr(obj, "item", None)
    if callable(item):
        return item()
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    return str(obj)
