"""Post-compile HLO analysis: collective byte accounting + roofline terms.

``cost_analysis()`` supplies FLOPs and HBM bytes but not collective traffic;
we parse the (SPMD-partitioned, per-device) HLO text and sum operand bytes of
every collective op, with per-op wire multipliers (ring algorithms):

  all-gather          1x result bytes   (each chip receives ~the full result)
  all-reduce          2x operand bytes  (reduce-scatter + all-gather phases)
  reduce-scatter      1x operand bytes
  all-to-all          1x operand bytes
  collective-permute  1x operand bytes

Hardware model (TPU v5e-like, per assignment): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_WIRE_MULT = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    if not dims:
        return _DTYPE_BYTES[dtype]
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum collective result bytes from (per-device, optimized) HLO text.

    Optimized HLO references operands by name without shapes, so we read the
    *result* type (between ``=`` and the op name).  For all-gather the result
    is the gathered (larger) buffer — matching the ring wire bytes; for
    all-reduce / reduce-scatter / all-to-all / collective-permute the result
    size equals (or bounds) the shard moved.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if f"{op}-done(" in line:
            continue  # count the -start, not the -done
        # result type: shapes between '=' and the op name
        shapes = _SHAPE_RE.findall(line[m.start(): m.end()])
        if not shapes:
            shapes = _SHAPE_RE.findall(line)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + nbytes
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
        stats.wire_bytes += nbytes * _WIRE_MULT[op]
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_wire_bytes: float
    model_flops_total: float
    n_chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_wire_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops across chips) — remat/redundancy waste."""
        total_hlo = self.flops_per_device * self.n_chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak sustained if the step ran at the roofline time:
        useful compute seconds / roofline step seconds."""
        useful_s = self.model_flops_total / (self.n_chips * PEAK_FLOPS)
        return useful_s / self.step_time_s if self.step_time_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_wire_bytes": self.collective_wire_bytes,
            "model_flops_total": self.model_flops_total,
            "n_chips": self.n_chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, model_flops_total: float, n_chips: int,
            hlo_text: Optional[str] = None) -> Dict:
    """Full per-cell analysis dict from a compiled executable."""
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = dict(ca) if ca else {}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    roof = Roofline(
        flops_per_device=float(cost.get("flops", 0.0)),
        hbm_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_wire_bytes=coll.wire_bytes,
        model_flops_total=model_flops_total,
        n_chips=n_chips,
    )
    return {
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem,
        "collectives": {
            "bytes_by_op": coll.bytes_by_op,
            "count_by_op": coll.count_by_op,
            "wire_bytes": coll.wire_bytes,
        },
        "roofline": roof.to_dict(),
    }
