"""Launch layer: production meshes, per-cell step builders, dry-run,
roofline analysis, training/serving entry points."""
