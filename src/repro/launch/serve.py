"""Serving launcher: online RPQ query service with TAPER maintenance.

    PYTHONPATH=src python -m repro.launch.serve --dataset provgen --ticks 10
"""
from __future__ import annotations

import argparse

from repro.core.rpq import parse_rpq
from repro.graphs.generators import musicbrainz_like, provgen_like
from repro.graphs.partition import hash_partition
from repro.serve.engine import GraphQueryEngine, ServeConfig
from repro.utils import get_logger
from repro.workload.stream import WorkloadStream

log = get_logger("launch.serve")

QUERIES = {
    "provgen": ["Entity.Entity.Entity", "Agent.Activity.Entity",
                "Entity.Activity.Agent"],
    "musicbrainz": ["Artist.Credit.Track.Medium",
                    "Artist.Credit.(Track|Recording).Credit.Artist",
                    "Area.Artist.(Artist|Label).Area"],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["provgen", "musicbrainz"],
                    default="provgen")
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=10)
    ap.add_argument("--batch", type=int, default=100)
    args = ap.parse_args()

    g = (provgen_like if args.dataset == "provgen" else musicbrainz_like)(
        args.n, seed=3)
    queries = [parse_rpq(q) for q in QUERIES[args.dataset]]
    stream = WorkloadStream(queries, period=float(args.ticks), seed=0)
    engine = GraphQueryEngine(
        g, hash_partition(g.n, args.k, seed=1), args.k,
        ServeConfig(min_requests_between_invocations=3 * args.batch))

    for tick in range(args.ticks):
        results = engine.serve_batch(stream.sample(args.batch))
        ipt = sum(r.ipt for r in results) / len(results)
        s = engine.stats()
        log.info("tick %d: ipt/request=%.2f invocations=%d drift=%.3f",
                 tick, ipt, s["invocations"], s["drift"])
        stream.advance(1.0)
    log.info("served %d requests total, %.2f ipt/request",
             engine.stats()["requests"], engine.stats()["ipt_per_request"])


if __name__ == "__main__":
    main()
