import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, ``lower + compile`` the step
function on the production meshes — (16, 16) single pod and (2, 16, 16)
multi-pod — and record memory analysis, cost analysis, and the collective
schedule to ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

The 512 placeholder host devices are forced in the FIRST TWO LINES above,
before any other import, because jax locks the device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import list_archs, shapes_for  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import chips_in, make_production_mesh  # noqa: E402
from repro.launch.specs import build_cell  # noqa: E402
from repro.utils import get_logger  # noqa: E402

log = get_logger("launch.dryrun")

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = OUT_DIR, overrides: dict | None = None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    if overrides and overrides.get("unroll"):
        mesh_name += "_unrolled"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    out_path = out_dir / f"{tag}.json"
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            plan = build_cell(arch, shape_name, mesh, **(overrides or {}))
            lowered = plan.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            analysis = hlo_analysis.analyze(
                compiled, plan.meta.get("model_flops", 0.0), chips_in(mesh))
        result.update(
            status="ok",
            step=plan.step_name,
            meta=plan.meta,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            **analysis,
        )
        # headline prints required by the assignment
        ma = result.get("memory_analysis", {})
        log.info("%s: OK lower=%.1fs compile=%.1fs mem=%s dominant=%s",
                 tag, t_lower, t_compile,
                 {k: f"{v/1e9:.2f}GB" for k, v in ma.items() if isinstance(v, int)},
                 result["roofline"]["dominant"])
    except Exception as e:
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        log.error("%s: FAILED %s", tag, e)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(result, indent=2, default=float))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer/attention scans (roofline analysis "
                         "variant: exact HLO flop counts, slower compile)")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_devices = len(jax.devices())
    assert n_devices == 512, f"expected 512 placeholder devices, got {n_devices}"

    failures = 0
    for arch in archs:
        shape_names = ([args.shape] if args.shape
                       else [s.name for s in shapes_for(arch)])
        for shape_name in shape_names:
            for multi in meshes:
                mesh_name = ("multi" if multi else "single") + (
                    "_unrolled" if args.unroll else "")
                out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
                if args.skip_existing and out_path.exists():
                    prev = json.loads(out_path.read_text())
                    if prev.get("status") == "ok":
                        continue
                res = run_cell(arch, shape_name, multi, out_dir,
                               overrides={'unroll': True} if args.unroll else None)
                if res["status"] != "ok":
                    failures += 1
    if failures:
        log.error("%d cells failed", failures)
        raise SystemExit(1)
    log.info("all cells passed")


if __name__ == "__main__":
    main()
