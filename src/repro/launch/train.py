"""Training launcher: ``--arch <id> --shape train_4k`` on the local device
set (reduced configs for CPU; the production mesh path is exercised by
dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config
from repro.optim import AdamW, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig
from repro.utils import get_logger

log = get_logger("launch.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (assignment) config instead of reduced")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.family != "lm":
        raise SystemExit("launch.train drives LM archs; see examples/ for "
                         "GNN and DLRM training drivers")
    if not args.full_config:
        cfg = cfg.reduced()

    from repro.data.lm import TokenPipeline
    from repro.models import transformer as tf

    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(learning_rate=cosine_schedule(1e-3, 20, args.steps))
    ostate = opt.init(params)
    step = jax.jit(tf.make_train_step(cfg, opt, remat=False))
    data = TokenPipeline(cfg.vocab, args.batch, args.seq_len, seed=0)

    def loss_and_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tf.loss_fn(p, batch, cfg), has_aux=True)(params)
        return grads, metrics

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                      checkpoint_dir=args.ckpt_dir,
                      compress_grads=args.compress_grads),
        step, params, ostate, data,
        grad_step_fn=jax.jit(loss_and_grads),
        apply_fn=jax.jit(lambda p, g, o: opt.update(p, g, o)),
    )
    trainer.try_resume()
    out = trainer.run()
    log.info("done: final loss %.4f", out["metrics"][-1]["loss"])


if __name__ == "__main__":
    main()
