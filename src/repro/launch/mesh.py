"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first
backend initialisation, and only dryrun.py is allowed to set the 512-device
flag (in its first two lines, before any other import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_devices: int | None = None):
    """Tiny mesh over however many (host) devices exist — used by sharding
    unit tests, which run with the default single CPU device."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def chips_in(mesh) -> int:
    return int(mesh.devices.size)
