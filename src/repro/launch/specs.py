"""Per-cell (architecture x input-shape) build plans for the dry-run.

``build_cell(arch, shape, mesh)`` returns everything `jax.jit(...).lower()`
needs: the step function, argument ShapeDtypeStructs (no allocation — the
eval_shape pattern), and in/out shardings resolved from logical axis rules.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DLRMConfig, GNNConfig, LMConfig, ShapeSpec, TaperSystemConfig
from repro.configs.registry import get_config, shapes_for
from repro.core.tpstry import synthetic_trie
from repro.core.visitor import _build_field_fn
from repro.distributed.sharding import LogicalAxisRules, rules_for
from repro.models import dlrm as dlrm_lib
from repro.models import transformer as tf
from repro.models.gnn import api as gnn_api
from repro.optim import AdamW

F32, BF16, I32, BOOL = jnp.float32, jnp.bfloat16, jnp.int32, jnp.bool_


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclass
class CellPlan:
    arch: str
    shape: ShapeSpec
    step_name: str
    step_fn: Callable
    args: Tuple[Any, ...]              # pytrees of ShapeDtypeStruct
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: Dict[str, Any] = field(default_factory=dict)
    mesh: Any = None
    rules: Any = None
    constrain_activations: bool = True

    def lower(self):
        from repro.distributed.sharding import activation_sharding

        jitted = jax.jit(
            self.step_fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
        )
        if self.constrain_activations and self.mesh is not None:
            with activation_sharding(self.mesh, self.rules):
                return jitted.lower(*self.args)
        return jitted.lower(*self.args)


def shape_init(init_fn, *args):
    """eval_shape an init that returns (params, logical): shapes without
    allocation, logical captured by side effect (it is static python)."""
    captured = {}

    def inner(rng):
        p, logical = init_fn(rng, *args)
        captured["logical"] = logical
        return p

    shapes = jax.eval_shape(inner, jax.random.PRNGKey(0))
    return shapes, captured["logical"]


def _shard_tree(mesh, rules, logical_tree, shapes_tree=None):
    from repro.distributed.sharding import tree_shardings

    return tree_shardings(mesh, logical_tree, shapes_tree, rules)


def _named(mesh, rules, *axes, shape=None):
    return NamedSharding(mesh, rules.spec(axes, shape, mesh))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(cfg: LMConfig, shape: ShapeSpec, mesh, rules,
             optimizer: Optional[AdamW] = None, remat: bool = True,
             unroll: bool = False) -> CellPlan:
    B = shape.dim("global_batch")
    S = shape.dim("seq_len")
    params_shapes, logical = shape_init(tf.init, cfg)
    p_shard = _shard_tree(mesh, rules, logical, params_shapes)
    n_active = cfg.n_active_params()

    if shape.kind == "train":
        opt = optimizer or AdamW(learning_rate=3e-4)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_shard = _shard_tree(mesh, rules, opt.state_logical_axes(logical), opt_shapes)
        batch = {
            "tokens": sds((B, S), I32),
            "labels": sds((B, S), I32),
        }
        b_shard = {
            "tokens": _named(mesh, rules, "batch", None, shape=(B, S)),
            "labels": _named(mesh, rules, "batch", None, shape=(B, S)),
        }
        step = tf.make_train_step(cfg, opt, remat=remat, unroll=unroll)
        model_flops = 6.0 * n_active * B * S \
            + 12.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * B * S * S / 2
        return CellPlan(
            cfg.name, shape, "train_step", step,
            (params_shapes, opt_shapes, batch),
            (p_shard, opt_shard, b_shard),
            (p_shard, opt_shard, None),
            {"model_flops": model_flops, "n_params": cfg.n_params(),
             "n_active": n_active, "tokens": B * S},
        )

    if shape.kind == "prefill":
        def prefill(params, tokens):
            logits, aux, cache = tf.forward(params, tokens, cfg,
                                            return_cache=True, unroll=unroll)
            return logits[:, -1, :], cache

        tokens = sds((B, S), I32)
        t_shard = _named(mesh, rules, "batch", None, shape=(B, S))
        cache_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else F32
        cache_shapes = {
            "k": sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.d_head), cache_dt),
            "v": sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.d_head), cache_dt),
            "pos": sds((), I32),
        }
        cache_shard = _shard_tree(mesh, rules, tf.cache_logical_axes(cfg),
                                  cache_shapes)
        out_shard = (_named(mesh, rules, "batch", "vocab"), cache_shard)
        model_flops = 2.0 * n_active * B * S \
            + 4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * B * S * S / 2
        return CellPlan(
            cfg.name, shape, "prefill_step", prefill,
            (params_shapes, tokens), (p_shard, t_shard), out_shard,
            {"model_flops": model_flops, "n_params": cfg.n_params(),
             "n_active": n_active, "tokens": B * S},
        )

    # decode cells: one new token against a seq_len KV cache
    long_ctx = S >= 262144
    cache_logical = tf.cache_logical_axes(cfg, long_context=long_ctx)
    cache = {
        "k": sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.d_head),
                 jnp.bfloat16 if cfg.dtype == "bfloat16" else F32),
        "v": sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.d_head),
                 jnp.bfloat16 if cfg.dtype == "bfloat16" else F32),
        "pos": sds((), I32),
    }
    cache_shard = _shard_tree(mesh, rules, cache_logical, cache)
    tokens = sds((B, 1), I32)
    t_shard = _named(mesh, rules, None if long_ctx else "batch", None, shape=(B, 1))

    def decode(params, cache, tokens):
        return tf.decode_step(params, cache, tokens, cfg, unroll=unroll)

    # decode flops: params once per token + attention against the cache
    model_flops = 2.0 * n_active * B \
        + 4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * B * S
    kv_bytes = 2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.d_head * 2
    return CellPlan(
        cfg.name, shape, "serve_step", decode,
        (params_shapes, cache, tokens),
        (p_shard, cache_shard, t_shard),
        ((_named(mesh, rules, None if long_ctx else "batch", None, "vocab"),
          cache_shard)),
        {"model_flops": model_flops, "n_params": cfg.n_params(),
         "n_active": n_active, "tokens": B, "kv_bytes": kv_bytes},
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_batch_specs(cfg: GNNConfig, shape: ShapeSpec, mesh, rules):
    d_feat = gnn_api.feature_dim(cfg, shape)
    if shape.name == "molecule":
        G = shape.dim("batch")
        N = G * shape.dim("n_nodes")
        E = G * shape.dim("n_edges")
    elif shape.name == "minibatch_lg":
        seeds = shape.dim("batch_nodes")
        f1, f2 = shape.dim("fanout1"), shape.dim("fanout2")
        N = seeds * (1 + f1 + f1 * f2)
        E = seeds * f1 + seeds * f1 * f2
    else:
        N = shape.dim("n_nodes")
        E = shape.dim("n_edges")
    batch = {
        "node_feat": sds((N, d_feat), F32),
        "edge_src": sds((E,), I32),
        "edge_dst": sds((E,), I32),
        "node_mask": sds((N,), BOOL),
        "edge_mask": sds((E,), BOOL),
    }
    shard = {
        "node_feat": _named(mesh, rules, "nodes", None, shape=(N, d_feat)),
        "edge_src": _named(mesh, rules, "edges", shape=(E,)),
        "edge_dst": _named(mesh, rules, "edges", shape=(E,)),
        "node_mask": _named(mesh, rules, "nodes", shape=(N,)),
        "edge_mask": _named(mesh, rules, "edges", shape=(E,)),
    }
    if gnn_api.needs_positions(cfg):
        batch["positions"] = sds((N, 3), F32)
        shard["positions"] = _named(mesh, rules, "nodes", None, shape=(N, 3))
    if shape.name == "molecule":
        batch["graph_id"] = sds((N,), I32)
        shard["graph_id"] = _named(mesh, rules, "nodes", shape=(N,))
    tshape, tdtype = gnn_api.target_spec(cfg, shape, N)
    batch["targets"] = sds(tshape, tdtype)
    shard["targets"] = _named(
        mesh, rules, "nodes" if tshape == (N,) else None, shape=tshape)
    return batch, shard, N, E, d_feat


def _gnn_model_flops(cfg: GNNConfig, N: int, E: int, d_feat: int) -> float:
    C, L = cfg.d_hidden, cfg.n_layers
    if cfg.kind == "gcn":
        dims = [d_feat] + [C] * (L - 1) + [cfg.n_classes]
        return sum(2.0 * N * a * b + 2.0 * E * a for a, b in zip(dims, dims[1:]))
    if cfg.kind == "gin":
        per = 2.0 * E * C + 2.0 * N * (C * C * 2)
        return L * per + 2.0 * N * d_feat * C
    S = (cfg.l_max + 1) ** 2
    if cfg.kind == "nequip":
        paths = (cfg.l_max + 1) ** 3  # upper bound on CG paths
        per = 2.0 * E * C * S * (2 * cfg.l_max + 1) * paths / (cfg.l_max + 1) \
            + 2.0 * N * C * C * S
        return L * per
    # equiformer_v2 (eSCN): rotation (S^1.5-ish) + per-m channel mixes
    wigner = sum((2 * l + 1) ** 2 for l in range(cfg.l_max + 1))
    per = 2.0 * E * C * wigner * 2 \
        + 2.0 * E * C * C * (2 * cfg.m_max + 1) \
        + 2.0 * N * C * C * 2
    return L * per


def _gnn_cell(cfg: GNNConfig, shape: ShapeSpec, mesh, rules,
              optimizer: Optional[AdamW] = None) -> CellPlan:
    batch, b_shard, N, E, d_feat = _gnn_batch_specs(cfg, shape, mesh, rules)
    params_shapes, logical = shape_init(gnn_api.init, cfg, shape)
    p_shard = _shard_tree(mesh, rules, logical, params_shapes)
    opt = optimizer or AdamW(learning_rate=1e-3, weight_decay=0.0)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    opt_shard = _shard_tree(mesh, rules, opt.state_logical_axes(logical), opt_shapes)
    step = gnn_api.make_train_step(cfg, shape, opt)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shapes))
    return CellPlan(
        cfg.name, shape, "train_step", step,
        (params_shapes, opt_shapes, batch),
        (p_shard, opt_shard, b_shard),
        (p_shard, opt_shard, None),
        {"model_flops": _gnn_model_flops(cfg, N, E, d_feat),
         "n_params": n_params, "nodes": N, "edges": E},
    )


# ---------------------------------------------------------------------------
# DLRM cells
# ---------------------------------------------------------------------------


def _dlrm_cell(cfg: DLRMConfig, shape: ShapeSpec, mesh, rules,
               optimizer: Optional[AdamW] = None) -> CellPlan:
    params_shapes, logical = shape_init(dlrm_lib.init, cfg)
    p_shard = _shard_tree(mesh, rules, logical, params_shapes)
    mlp_flops = 0.0
    dims = (cfg.n_dense,) + cfg.bot_mlp
    mlp_flops += sum(2.0 * a * b for a, b in zip(dims, dims[1:]))
    n_feat = cfg.n_sparse + 1
    inter_in = n_feat * (n_feat - 1) // 2 + cfg.bot_mlp[-1]
    dims = (inter_in,) + cfg.top_mlp
    mlp_flops += sum(2.0 * a * b for a, b in zip(dims, dims[1:]))
    inter_flops = 2.0 * n_feat * n_feat * cfg.embed_dim

    if shape.kind == "retrieval":
        n_cand = shape.dim("n_candidates")
        query = {"dense": sds((1, cfg.n_dense), F32)}
        cands = sds((n_cand, cfg.bot_mlp[-1]), F32)

        def retrieve(params, query, candidates):
            return dlrm_lib.retrieval_step(params, query, candidates)

        return CellPlan(
            cfg.name, shape, "retrieval_step", retrieve,
            (params_shapes, query, cands),
            (p_shard, {"dense": _named(mesh, rules, None, None)},
             _named(mesh, rules, "candidates", None, shape=(n_cand, cfg.bot_mlp[-1]))),
            None,
            {"model_flops": 2.0 * n_cand * cfg.bot_mlp[-1],
             "n_params": cfg.n_params(), "batch": 1},
        )

    B = shape.dim("batch")
    batch = {
        "dense": sds((B, cfg.n_dense), F32),
        "sparse": sds((B, cfg.n_sparse), I32),
    }
    b_shard = {
        "dense": _named(mesh, rules, "batch", None, shape=(B, cfg.n_dense)),
        "sparse": _named(mesh, rules, "batch", None, shape=(B, cfg.n_sparse)),
    }
    per_ex_flops = mlp_flops + inter_flops
    lookup_bytes = B * cfg.n_sparse * cfg.embed_dim * 4

    if shape.kind == "serve":
        def serve(params, batch):
            return dlrm_lib.serve_step(params, batch, cfg)

        return CellPlan(
            cfg.name, shape, "serve_step", serve,
            (params_shapes, batch), (p_shard, b_shard),
            _named(mesh, rules, "batch"),
            {"model_flops": per_ex_flops * B, "n_params": cfg.n_params(),
             "batch": B, "lookup_bytes": lookup_bytes},
        )

    batch["labels"] = sds((B,), F32)
    b_shard["labels"] = _named(mesh, rules, "batch", shape=(B,))
    opt = optimizer or AdamW(learning_rate=1e-3, weight_decay=0.0)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    opt_shard = _shard_tree(mesh, rules, opt.state_logical_axes(logical), opt_shapes)
    step = dlrm_lib.make_train_step(cfg, opt)
    return CellPlan(
        cfg.name, shape, "train_step", step,
        (params_shapes, opt_shapes, batch),
        (p_shard, opt_shard, b_shard),
        (p_shard, opt_shard, None),
        {"model_flops": 3.0 * per_ex_flops * B, "n_params": cfg.n_params(),
         "batch": B, "lookup_bytes": lookup_bytes},
    )


# ---------------------------------------------------------------------------
# TAPER refine-step cell (the paper's technique itself)
# ---------------------------------------------------------------------------


def _taper_cell(cfg: TaperSystemConfig, shape: ShapeSpec, mesh, rules,
                fused: bool = True, dense_ext_to: bool = False) -> CellPlan:
    n = shape.dim("n_vertices")
    m = shape.dim("n_edges")
    trie = synthetic_trie(cfg.n_labels, cfg.trie_depth, branching=2)
    k = cfg.k_partitions
    key = (trie.topology_signature(), k, trie.max_depth, n, m, fused, dense_ext_to)
    fn = _build_field_fn(key, trie, k, trie.max_depth, fused=fused,
                         dense_ext_to=dense_ext_to)

    args = (
        sds((m,), I32), sds((m,), I32),                  # src, dst
        sds((n,), I32),                                  # labels
        sds((n, cfg.n_labels), I32),                     # cnt
        sds((cfg.n_labels,), I32),                       # label vertex counts
        sds((n,), I32),                                  # part
        sds((trie.n_nodes,), F32), sds((trie.n_nodes,), F32),  # p, cond_p
    )
    e = _named(mesh, rules, "edges", shape=(m,))
    v = _named(mesh, rules, "nodes", shape=(n,))
    rep = NamedSharding(mesh, P())
    in_sh = (e, e, v, _named(mesh, rules, "nodes", None, shape=(n, cfg.n_labels)), rep, v, rep, rep)

    def refine(src, dst, labels, cnt, lab_vcount, part, p, cond_p):
        return fn(src, dst, labels, cnt, lab_vcount, part, p, cond_p, n=n, m=m)

    # outputs: alpha (n,N), pr (n,), mass (m,), extro (n,), extroversion (n,)
    # [, ext_to (n, k)] — all sharded along their vertex/edge dim
    vN = _named(mesh, rules, "nodes", None, shape=(n, trie.n_nodes))
    vk = _named(mesh, rules, "nodes", None, shape=(n, k))
    out_sh = (vN, v, e, v, v) + ((vk,) if dense_ext_to else ())

    # DP flops: per depth>=2 trie node, one gather-multiply-scatter over edges
    steps = int((trie.depth >= 2).sum())
    model_flops = 4.0 * m * steps + 4.0 * m * trie.n_nodes
    return CellPlan(
        cfg.name, shape, "taper_refine_step", refine,
        args, in_sh, out_sh,
        {"model_flops": model_flops, "n_vertices": n, "n_edges": m,
         "trie_nodes": trie.n_nodes, "k": k},
    )


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               rules: Optional[LogicalAxisRules] = None,
               constrain_activations: bool = True, **kw) -> CellPlan:
    cfg = get_config(arch)
    rules = rules or rules_for(mesh)
    shape = next(s for s in shapes_for(arch) if s.name == shape_name)

    def pick(*names):
        return {k: v for k, v in kw.items() if k in names}

    if cfg.family == "lm":
        plan = _lm_cell(cfg, shape, mesh, rules,
                        **pick("optimizer", "remat", "unroll"))
    elif cfg.family == "gnn":
        plan = _gnn_cell(cfg, shape, mesh, rules, **pick("optimizer"))
    elif cfg.family == "recsys":
        plan = _dlrm_cell(cfg, shape, mesh, rules, **pick("optimizer"))
    elif cfg.family == "taper":
        plan = _taper_cell(cfg, shape, mesh, rules,
                           **pick("fused", "dense_ext_to"))
    else:
        raise ValueError(cfg.family)
    plan.mesh = mesh
    plan.rules = rules
    plan.constrain_activations = constrain_activations
    return plan


def all_cells():
    """Every (arch, shape) pair in the assignment (skips documented in
    configs.registry.shapes_for)."""
    out = []
    from repro.configs.registry import list_archs

    for arch in list_archs():
        for s in shapes_for(arch):
            out.append((arch, s.name))
    return out
