"""Roofline report generator: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (single-pod, per assignment).

Prefers the scan-unrolled analysis variant for LM cells (exact HLO flop
counts — XLA's cost analysis counts a while-loop body once, so the scanned
module under-reports by the trip count; see EXPERIMENTS.md §Methodology).

    PYTHONPATH=src python -m repro.launch.roofline [--out experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, Optional

from repro.launch.hlo_analysis import HBM_BW, LINK_BW, PEAK_FLOPS

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def _advice(d: Dict) -> str:
    r = d["roofline"]
    dom = r["dominant"]
    kind = d.get("step", "")
    if dom == "memory":
        if "train" in kind:
            return ("less remat / bf16 activations; fuse the optimizer "
                    "update to cut HBM round-trips")
        if "serve" in kind or "decode" in kind:
            return "KV-cache quantisation (int8) halves the bytes-bound term"
        return "fuse gather+scatter (Pallas segment kernels) to stop spilling"
    if dom == "collective":
        if "train" in kind:
            return ("reduce-scatter grads instead of all-reduce; overlap "
                    "FSDP all-gathers with layer compute")
        if "moe" in d["arch"] or "kimi" in d["arch"] or "olmoe" in d["arch"]:
            return "shard_map all-to-all dispatch; TAPER expert placement"
        return "shard the gather/scatter along the already-local axis"
    return "increase per-chip batch; MXU-align tile shapes"


def load_cells(mesh: str = "single") -> Dict:
    cells = {}
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        if d.get("status") == "ok":
            cells[(d["arch"], d["shape"])] = d
    # prefer unrolled analysis variants where present
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}_unrolled.json")):
        d = json.loads(p.read_text())
        if d.get("status") == "ok":
            d["analysis_variant"] = "unrolled"
            cells[(d["arch"], d["shape"])] = d
    return cells


def table(cells: Dict) -> str:
    rows = [
        "| arch | shape | step | compute | memory | collective | dominant | "
        "MODEL_FLOPS | useful ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), d in sorted(cells.items()):
        r = d["roofline"]
        var = "*" if d.get("analysis_variant") == "unrolled" else ""
        rows.append(
            f"| {arch}{var} | {shape} | {d['step']} | {_fmt_s(r['compute_s'])} "
            f"| {_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['model_flops_total']:.3g} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {_advice(d)} |"
        )
    return "\n".join(rows)


def memory_table(cells_single: Dict, cells_multi: Dict) -> str:
    rows = [
        "| arch | shape | mesh | args GB/dev | temp GB/dev | fits v5e 16GB | "
        "collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for mesh_name, cells in (("single", cells_single), ("multi", cells_multi)):
        for (arch, shape), d in sorted(cells.items()):
            if d.get("analysis_variant") == "unrolled":
                continue
            ma = d.get("memory_analysis", {})
            args = ma.get("argument_size_in_bytes", 0) / 1e9
            temp = ma.get("temp_size_in_bytes", 0) / 1e9
            fits = "yes" if (args + temp) < 16 else "NO"
            cc = d.get("collectives", {}).get("count_by_op", {})
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in cc.items())
            rows.append(f"| {arch} | {shape} | {mesh_name} | {args:.2f} "
                        f"| {temp:.2f} | {fits} | {cstr} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(DRYRUN_DIR.parent / "roofline.md"))
    args = ap.parse_args()
    single = load_cells("single")
    multi = load_cells("multi")
    text = (
        "# Roofline (single-pod 16x16, v5e model: "
        f"{PEAK_FLOPS / 1e12:.0f} TFLOP/s bf16, {HBM_BW / 1e9:.0f} GB/s HBM, "
        f"{LINK_BW / 1e9:.0f} GB/s/link)\n\n"
        "`*` = scan-unrolled analysis variant (exact HLO flops).\n\n"
        + table(single)
        + "\n\n# Dry-run memory / collective schedule (both meshes)\n\n"
        + memory_table(single, multi)
        + "\n"
    )
    Path(args.out).write_text(text)
    print(text)


if __name__ == "__main__":
    main()
