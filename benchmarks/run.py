"""Benchmark runner: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV (plus roofline/dry-run summaries if
artifacts exist).  Scale via REPRO_BENCH_N (default 20000 vertices).

``--json PATH`` additionally writes the full report machine-readable —
every row with its structured ``metrics`` dict (speedups, halo ratios,
throughputs) plus the run's scale/device context — so successive PRs leave
a comparable ``BENCH_*.json`` perf trajectory in the repo.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import traceback

from benchmarks.common import Report

CORE = [
    "fig7_convergence",
    "fig8_approaches",
    "fig9_queries",
    "fig10_drift",
    "fig11_online",
    "online_topology",
    "swap_scale",
    # multi-device field scaling; under run.py it inherits whatever device
    # count jax already initialised (run standalone for the 8-way mesh)
    "field_shard",
    # batched frontier enumeration vs the DFS oracle + multi-worker serving
    # scaling (pure numpy/threads; speedup gated at N>=20000, scaling gated
    # standalone on >=4-core hosts)
    "query_enum",
    # async serving loop: overlap win vs stop-the-world + warm dirty shards
    # (same device-count caveat as field_shard)
    "serve_loop",
    # crash-safe serving: snapshot cost, WAL replay catch-up, degraded floor
    "recovery",
    # replicated cluster: follower catch-up replay, fenced failover to
    # first answer, read throughput with one crashed replica
    "cluster_failover",
    # observability overhead: traced vs untraced serving throughput
    # (<=5% gated standalone), trace_sample_rate=0 ~free
    "obs_overhead",
    # closed-loop overload protection: flash-crowd brownout shedding
    # defends the hot-class SLO, goodput floor + hysteretic recovery
    "overload",
]

# integration benchmarks: skipped (by name) only when a genuinely optional
# third-party dependency is missing — an ImportError raised *inside* repro/
# benchmark code is a real bug and propagates
INTEGRATION = ["gnn_halo", "dlrm_span", "expert_placement"]

_FIRST_PARTY_PREFIXES = ("repro", "benchmarks")


def load_modules():
    modules = [(name, importlib.import_module(f"benchmarks.{name}"))
               for name in CORE]
    for name in INTEGRATION:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except ImportError as e:
            missing = getattr(e, "name", None) or ""
            top = missing.split(".")[0]
            if top and top not in _FIRST_PARTY_PREFIXES:
                print(f"SKIP {name}: optional dependency {missing!r} "
                      "not installed", file=sys.stderr)
                continue
            raise  # ImportError from our own transitive code: surface it
        modules.append((name, mod))
    return modules


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the report (rows + per-row metrics "
                         "dicts + run context) as JSON to PATH")
    args = ap.parse_args(argv)

    report = Report()
    failures = 0
    ran = []
    for name, mod in load_modules():
        try:
            mod.run(report)
            ran.append(name)
        except Exception:
            failures += 1
            print(f"BENCHMARK {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    report.emit()
    if args.json:
        doc = report.to_json()
        doc["modules"] = ran
        doc["failures"] = failures
        if "jax" in sys.modules:
            doc["devices"] = len(sys.modules["jax"].devices())
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=False)
            fh.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
