"""Benchmark runner: one module per paper figure/table.

Prints ``name,us_per_call,derived`` CSV (plus roofline/dry-run summaries if
artifacts exist).  Scale via REPRO_BENCH_N (default 20000 vertices).
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import Report


def main() -> None:
    from benchmarks import (
        fig7_convergence,
        fig8_approaches,
        fig9_queries,
        fig10_drift,
        fig11_online,
        swap_scale,
    )

    modules = [
        ("fig7_convergence", fig7_convergence),
        ("fig8_approaches", fig8_approaches),
        ("fig9_queries", fig9_queries),
        ("fig10_drift", fig10_drift),
        ("fig11_online", fig11_online),
        ("swap_scale", swap_scale),
    ]
    # integration benchmarks (registered lazily; require the model substrate)
    try:
        from benchmarks import gnn_halo, dlrm_span, expert_placement

        modules += [
            ("gnn_halo", gnn_halo),
            ("dlrm_span", dlrm_span),
            ("expert_placement", expert_placement),
        ]
    except ImportError:
        pass

    report = Report()
    failures = 0
    for name, mod in modules:
        try:
            mod.run(report)
        except Exception:
            failures += 1
            print(f"BENCHMARK {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    report.emit()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
