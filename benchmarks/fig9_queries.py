"""Fig. 9 — per-query ipt under a skewed workload (MusicBrainz).

Workload snapshot: MQ1 10%, MQ2 20%, MQ3 70% (§6.2.3).  Paper's mechanism
claim: TAPER prioritises vertex swaps that internalise the paths of the most
frequent queries.  We report (a) per-query ipt for Metis vs Metis+TAPER
under the skewed workload, and (b) a direct mechanism check — refining with
*reversed* frequencies and verifying each query fares better under the
workload that weights it more.  (The paper's exact per-query ordering
relative to Metis is a property of the MusicBrainz dataset; the mechanism
check is the dataset-independent form of the claim.)
"""
from __future__ import annotations

import time
from typing import Optional

from benchmarks.common import MQ, Report, baselines, dataset, taper_for
from repro.workload.executor import QueryExecutor

FREQS = {"MQ1": 0.1, "MQ2": 0.2, "MQ3": 0.7}


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    g = dataset("musicbrainz")
    ex = QueryExecutor(g)
    hash_p, metis_p = baselines(g)
    taper = taper_for(g)

    w_skew = [(MQ[n], FREQS[n]) for n in ("MQ1", "MQ2", "MQ3")]
    w_rev = [(MQ["MQ1"], 0.7), (MQ["MQ2"], 0.2), (MQ["MQ3"], 0.1)]

    t0 = time.perf_counter()
    part_skew = taper.invoke(metis_p, w_skew).final_part
    part_rev = taper.invoke(metis_p, w_rev).final_part
    dt = time.perf_counter() - t0

    for qname, q in MQ.items():
        ipt_h = ex.ipt(q, hash_p)
        ipt_m = ex.ipt(q, metis_p)
        ipt_t = ex.ipt(q, part_skew)
        report.add(
            f"fig9/{qname}", dt,
            f"freq={FREQS[qname]:.0%} ipt_hash={ipt_h:.0f} ipt_metis={ipt_m:.0f} "
            f"ipt_metis+taper={ipt_t:.0f} vs_metis={ipt_t / max(ipt_m, 1e-9):.2f}",
        )

    # mechanism check: each query should do better under the workload that
    # weights it more
    mq1_better_when_heavy = ex.ipt(MQ["MQ1"], part_rev) <= ex.ipt(MQ["MQ1"], part_skew)
    mq3_better_when_heavy = ex.ipt(MQ["MQ3"], part_skew) <= ex.ipt(MQ["MQ3"], part_rev)
    report.add(
        "fig9/frequency_mechanism", dt,
        f"mq1_better_under_mq1heavy={mq1_better_when_heavy} "
        f"mq3_better_under_mq3heavy={mq3_better_when_heavy}",
    )
    return report


if __name__ == "__main__":
    run().emit()
