"""Shared benchmark fixtures: datasets, workloads, reporting.

The paper evaluates on a ~10M-vertex MusicBrainz subset and a ~1M-vertex
ProvGen graph with k=8 partitions (§6.1).  We scale the graphs down to run
on one CPU container (size configurable via REPRO_BENCH_N); everything else
follows the paper: the same query patterns (MQ1-3, PQ1-4), k=8, 5% balance,
ipt as the quality metric.
"""
from __future__ import annotations

import csv
import io
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ, parse_rpq
from repro.core.taper import Taper, TaperConfig
from repro.graphs.generators import musicbrainz_like, provgen_like
from repro.graphs.graph import LabelledGraph
from repro.graphs.partition import hash_partition, metis_like_partition
from repro.workload.executor import QueryExecutor

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "20000"))
K = 8  # paper §6.1: "a reasonable number of partitions (8)"


# -- query workloads (paper §6.1.2) ------------------------------------------

MQ = {
    "MQ1": parse_rpq("Area.Artist.(Artist|Label).Area"),
    "MQ2": parse_rpq("Artist.Credit.(Track|Recording).Credit.Artist"),
    "MQ3": parse_rpq("Artist.Credit.Track.Medium"),
}

PQ = {
    "PQ1": parse_rpq("Entity.(Entity)*.Entity"),
    "PQ2": parse_rpq("Agent.Activity.Entity.Entity.Activity.Agent"),
    "PQ3": parse_rpq("(Entity)*.Activity.Entity"),
    "PQ4": parse_rpq("Entity.Activity.(Agent)*"),
}


def musicbrainz_workload(freqs=(0.2, 0.3, 0.5)) -> List[Tuple[RPQ, float]]:
    return list(zip(MQ.values(), freqs))


def provgen_workload(freqs=(0.4, 0.2, 0.2, 0.2)) -> List[Tuple[RPQ, float]]:
    return list(zip(PQ.values(), freqs))


# -- datasets ------------------------------------------------------------------


_GRAPH_CACHE: Dict[Tuple, LabelledGraph] = {}


def dataset(name: str, n: Optional[int] = None) -> LabelledGraph:
    n = n or BENCH_N
    key = (name, n)
    if key not in _GRAPH_CACHE:
        if name == "musicbrainz":
            _GRAPH_CACHE[key] = musicbrainz_like(n, avg_degree=6.0, seed=13)
        elif name == "provgen":
            _GRAPH_CACHE[key] = provgen_like(n, avg_degree=6.0, seed=11)
        else:
            raise ValueError(name)
    return _GRAPH_CACHE[key]


def workload_for(name: str) -> List[Tuple[RPQ, float]]:
    return musicbrainz_workload() if name == "musicbrainz" else provgen_workload()


# -- result reporting -----------------------------------------------------------


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    #: machine-readable measurements (speedups, ratios, counts) — the
    #: ``run.py --json`` export; the ``derived`` string stays human-first
    metrics: Dict[str, object] = field(default_factory=dict)


class Report:
    """Collects ``name,us_per_call,derived`` rows (benchmarks/run.py contract)."""

    def __init__(self):
        self.rows: List[Row] = []

    def add(self, name: str, seconds: float, derived: str,
            metrics: Optional[Dict[str, object]] = None) -> None:
        self.rows.append(Row(name, seconds * 1e6, derived, dict(metrics or {})))

    def timeit(self, name: str, fn: Callable, derived_fn: Callable[[object], str]):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        self.add(name, dt, derived_fn(out))
        return out

    def emit(self, fh=None) -> str:
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow(["name", "us_per_call", "derived"])
        for r in self.rows:
            w.writerow([r.name, f"{r.us_per_call:.1f}", r.derived])
        text = buf.getvalue()
        print(text if fh is None else text, file=fh, end="")
        return text

    def to_json(self) -> Dict[str, object]:
        """Machine-readable export (``benchmarks/run.py --json PATH``)."""
        return {
            "bench_n": BENCH_N,
            "k": K,
            "rows": [
                {"name": r.name, "us_per_call": round(r.us_per_call, 1),
                 "derived": r.derived, "metrics": r.metrics}
                for r in self.rows
            ],
        }


def taper_for(g: LabelledGraph, **overrides) -> Taper:
    kwargs = {"max_iterations": 8, "seed": 0}
    kwargs.update(overrides)
    return Taper(g, K, TaperConfig(**kwargs))


def baselines(g: LabelledGraph):
    """(hash, metis-like) starting partitionings (paper §6.1)."""
    return hash_partition(g.n, K, seed=1), metis_like_partition(g, K, seed=0)
