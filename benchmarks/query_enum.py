"""Batched path enumeration + multi-worker serving throughput.

Acceptance benchmark for the PR-7 request path.  Two claims:

* **enum speedup** — one serving micro-batch enumerated by the compiled
  frontier-batched engine (``QueryExecutor.enumerate_paths_many``: one
  vectorised sweep per depth advances every live prefix of every distinct
  query) vs the recursive-DFS reference (``enumerate_paths_ref`` per
  distinct query — exactly the pre-PR request path, which already deduped
  the micro-batch).  Results are asserted bit-identical; the speedup is
  gated **>= 4x at N >= 20000** (the acceptance scale — at toy N the
  per-sweep numpy dispatch overhead dominates and the ratio is reported
  but not gated).

* **multi-worker scaling** — sustained requests/sec of the threaded
  ``ServingLoop`` draining one shared request queue with 1 vs 2 vs 4
  executor workers on the serve_loop request stream.  The enumeration
  sweeps are numpy ops that release the GIL, so workers overlap on real
  cores; the 4-worker ratio is gated **>= 2x** only when run standalone on
  a machine with >= 4 CPUs (this container has 1; CI runners gate it).

Scale via ``REPRO_BENCH_N`` (default 20000),
``REPRO_QUERY_ENUM_REQUESTS`` (serving budget, default 600).
"""
from __future__ import annotations

import os
import time
from typing import List, Optional

import numpy as np

_STANDALONE = __name__ == "__main__"

from benchmarks.common import BENCH_N, K, Report, dataset, workload_for
from repro.core.online import OnlinePolicy
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.workload.executor import QueryExecutor
from repro.workload.stream import WorkloadStream

#: serving-phase request budget per worker configuration
BUDGET = int(os.environ.get("REPRO_QUERY_ENUM_REQUESTS", "600"))
MICRO_BATCH = 16
#: requests in one enumeration micro-batch (duplicates included, as served)
ENUM_BATCH = 64
ENUM_REPS = 20
MAX_RESULTS = 32
IN_FLIGHT = 64
WORKER_COUNTS = (1, 2, 4)
#: acceptance gates (ISSUE 7): enum >= 4x at the N=20000 scale; 4-worker
#: serving >= 2x vs 1 when the host actually has the cores
ENUM_SPEEDUP_MIN = 4.0
SCALING_MIN = 2.0


def _enum_speedup(report: Report, n: int,
                  name: str = "query_enum/microbatch") -> None:
    g = dataset("musicbrainz", n)
    ex = QueryExecutor(g)
    rng = np.random.default_rng(5)
    part = rng.integers(0, K, g.n)
    queries = [q for q, _ in workload_for("musicbrainz")]
    batch = [queries[int(rng.integers(0, len(queries)))]
             for _ in range(ENUM_BATCH)]
    distinct = list({q.qhash: q for q in batch}.values())

    # warm plans, DP rows and the starts cache on both sides
    ref_results = {q.qhash: ex.enumerate_paths_ref(q, MAX_RESULTS, part)
                   for q in distinct}
    batched = ex.enumerate_paths_many(batch, MAX_RESULTS, part)
    for q, got in zip(batch, batched):
        assert got == ref_results[q.qhash], \
            f"batched enumeration diverged from the DFS oracle on {q.to_text()}"

    t0 = time.perf_counter()
    for _ in range(ENUM_REPS):
        for q in distinct:
            ex.enumerate_paths_ref(q, MAX_RESULTS, part)
    t_ref = (time.perf_counter() - t0) / ENUM_REPS
    stats = {}
    t0 = time.perf_counter()
    for _ in range(ENUM_REPS):
        ex.enumerate_paths_many(batch, MAX_RESULTS, part, stats=stats)
    t_batched = (time.perf_counter() - t0) / ENUM_REPS
    speedup = t_ref / max(t_batched, 1e-12)
    report.add(
        name, t_batched,
        f"n={g.n} batch={ENUM_BATCH} distinct={len(distinct)} "
        f"mr={MAX_RESULTS} ref_ms={1e3 * t_ref:.2f} "
        f"batched_ms={1e3 * t_batched:.2f} speedup={speedup:.1f}x "
        f"target>={ENUM_SPEEDUP_MIN:g}x@N>=20000 "
        f"sweeps={stats['enum_sweeps']} rows={stats['frontier_rows']}",
        metrics={"speedup": round(speedup, 2), "ref_s": t_ref,
                 "batched_s": t_batched,
                 "enum_sweeps": stats["enum_sweeps"],
                 "frontier_rows": stats["frontier_rows"]})
    if n >= 20000:
        assert speedup >= ENUM_SPEEDUP_MIN, (
            f"batched enumeration must be >= {ENUM_SPEEDUP_MIN:g}x the DFS "
            f"reference at N={n}, got {speedup:.2f}x")


def _drive(loop: ServingLoop, budget: int) -> float:
    """Feed ``budget`` requests (bounded in-flight window), wait out every
    ticket; returns the wall seconds of the serving phase."""
    ws = WorkloadStream(
        [q for q, _ in workload_for("musicbrainz")], period=6.0, seed=3)
    tickets: List = []
    t0 = time.perf_counter()
    offered = 0
    while offered < budget:
        pending = sum(1 for t in tickets if not t.done.is_set())
        chunk = min(budget - offered, max(0, IN_FLIGHT - pending))
        if chunk == 0:
            time.sleep(0.0005)
            continue
        ws.advance(chunk / 100.0)
        for q in ws.sample(chunk):
            t = loop.submit(q)
            while not t.accepted:
                time.sleep(min(t.retry_after_s, 0.005))
                t = loop.submit(q)
            tickets.append(t)
        offered += chunk
    for t in tickets:
        t.wait(timeout=600.0)
    return time.perf_counter() - t0


def _worker_scaling(report: Report, n: int) -> None:
    g0 = dataset("musicbrainz", n)
    qps = {}
    for n_workers in WORKER_COUNTS:
        loop = ServingLoop(
            g0.copy(), K,
            # isolate executor scaling: no invocations during the run
            policy=OnlinePolicy(cadence=10 ** 9,
                                bootstrap_after_ticks=10 ** 9),
            config=ServeLoopConfig(
                n_workers=n_workers, micro_batch=MICRO_BATCH,
                max_queue_depth=128, batch_wait_s=0.002,
                max_results_per_query=MAX_RESULTS)).start()
        _drive(loop, BUDGET // 4)                      # warm-up
        wall = _drive(loop, BUDGET)
        stats = loop.stop()
        qps[n_workers] = BUDGET / max(wall, 1e-9)
        report.add(
            f"query_enum/serving_{n_workers}w", wall / BUDGET,
            f"n={g0.n} workers={n_workers} "
            f"qps={qps[n_workers]:.0f} "
            f"p50_ms={1e3 * stats['latency_p50_s']:.2f} "
            f"p99_ms={1e3 * stats['latency_p99_s']:.2f} "
            f"workers_reporting={stats['workers_reporting']:.0f} "
            f"sweeps_per_batch={stats['enum_sweeps_per_batch']:.1f}",
            metrics={"qps": round(qps[n_workers], 1),
                     "n_workers": n_workers,
                     "workers_reporting": stats["workers_reporting"]})
    scaling = qps[4] / max(qps[1], 1e-9)
    cores = os.cpu_count() or 1
    report.add(
        "query_enum/scaling", 0.0,
        f"qps_1w={qps[1]:.0f} qps_2w={qps[2]:.0f} qps_4w={qps[4]:.0f} "
        f"scaling_4w={scaling:.2f}x target>={SCALING_MIN:g}x@cores>=4 "
        f"cores={cores}",
        metrics={"scaling_4w": round(scaling, 2), "cores": cores,
                 "qps": {str(w): round(qps[w], 1) for w in WORKER_COUNTS}})
    if _STANDALONE and cores >= 4:
        assert scaling >= SCALING_MIN, (
            f"4-worker serving must sustain >= {SCALING_MIN:g}x the "
            f"single-worker throughput on a {cores}-core host, "
            f"got {scaling:.2f}x")


def run(report: Optional[Report] = None, n: int = BENCH_N) -> Report:
    report = report or Report()
    _enum_speedup(report, n)
    if n < 20000:
        # the acceptance gate lives at N=20000; at toy BENCH_N the sweep
        # dispatch overhead dominates, so run (and gate) the real scale too
        # — enumeration only, a few hundred ms
        _enum_speedup(report, 20000, name="query_enum/microbatch_acceptance")
    _worker_scaling(report, n)
    return report


if __name__ == "__main__":
    run().emit()
