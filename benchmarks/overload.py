import sys

_STANDALONE = "jax" not in sys.modules

__doc__ = """Flash-crowd overload: brownout shedding defends the hot SLO.

Acceptance benchmark for the PR-10 control loops.  One serving loop with
the brownout controller enabled answers four phases of classed traffic:

* **baseline** — hot-only at a sustainable rate; measures the hot
  throughput and p99 the goodput and SLO gates are scored against;
* **calibration** — a few rounds at the surge's hot demand (3x), before
  any budget is armed.  This measures what the *controller's own*
  bucket-quantile estimator reports for the post-shed steady state, and
  the budget is then placed so the clear threshold
  (``clear_ratio * budget``) sits between the baseline estimate and the
  post-shed estimate.  Calibrating in estimator space matters: the
  registry histogram's buckets are coarse, so a threshold placed from
  exact percentiles can land where the estimator cannot discriminate
  the two states, and the ladder flaps;
* **flash crowd** — 4x the baseline demand (3x hot + 1x cold per round,
  arrivals interleaved).  Pre-shed, hot requests queue behind the full
  crowd and the hot latency breaches the budget, so the controller
  walks the shed ladder up; at the top the cold class is rejected
  outright and the hot class gets the capacity back.  The shed state
  runs *within* the budget but *above* the clear threshold, so the
  ladder holds stable under the sustained surge instead of flapping
  cold traffic back in;
* **recovery** — demand drops back to baseline; the first controller
  window that observes the drop is all-clear (below the clear
  threshold), so the ladder steps down and cold admission re-opens.

Controller windows run on an injected clock advanced once per round, so
window boundaries are load-aligned and deterministic; the latencies in
the histograms are real measured wall times.

Claims measured (asserted standalone; reported under ``run.py``):

* the surge actually engaged the brownout: shed level rose and cold
  requests were rejected with ``reason="brownout"``;
* the hot p99 over the post-shed half of the surge is within the SLO
  budget — shedding cold bought the hot class its latency back;
* hot goodput under the surge is >= 0.7x the pre-overload hot
  throughput (capacity went to hot work, not to a collapse);
* admission re-opens within one controller window of the load dropping
  (the first all-clear window steps the ladder down), and the ladder
  fully re-opens within a few more windows.

Scale via ``REPRO_BENCH_N`` (default 20000 vertices) and
``REPRO_OVERLOAD_ROUNDS`` (default 16 surge rounds).
"""

import os
import time
from typing import List, Optional

import numpy as np

from benchmarks.common import K, Report, workload_for
from repro.core.online import OnlinePolicy
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.serve.control import ControlConfig, WindowedQuantile
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.serve.queueing import Rejection

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "20000"))
# surge rounds: long enough that the steady shed state dominates the
# few ramp-up rounds the controller needs to walk the ladder up
ROUNDS = int(os.environ.get("REPRO_OVERLOAD_ROUNDS", "16"))
#: hot requests per baseline round
HOT = 16
SURGE = 4  # flash-crowd multiplier: 3x hot + 1x cold per round
SURGE_HOT = 3  # hot share of the surge (the rest is cold)
#: fraction of the clear-threshold -> budget span (budget = thr / ratio)
CLEAR_RATIO = 0.6
GOODPUT_FLOOR = 0.7
MICRO_BATCH = 16
CALIBRATION_ROUNDS = 4


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _make_loop(n: int, clk: _Clock) -> ServingLoop:
    ctl = ControlConfig(
        slo_budget_s={"hot": 9e9},  # armed after the calibration phase
        window_s=1.0, min_window_samples=8, shed_levels=2,
        # control on p95, not p99: a window holds only ~16-64 samples, so
        # its p99 is the single slowest request — one OS hiccup would flap
        # the ladder.  The p95 estimate is rank-based and stable.
        breach_quantile=0.95,
        clear_ratio=CLEAR_RATIO, clear_windows=1, clock=clk)
    g = musicbrainz_like(n, avg_degree=6.0, seed=13)
    return ServingLoop(
        g, K,
        taper_config=TaperConfig(max_iterations=2),
        # bootstrap fires during warm-up; the huge cadence keeps the
        # measured phases invocation-free so they time the serve path
        policy=OnlinePolicy(bootstrap_after_ticks=0, cadence=10 ** 9,
                            min_interval=0, dirty_fraction=2.0,
                            drift_l1=9e9),
        config=ServeLoopConfig(micro_batch=MICRO_BATCH,
                               max_queue_depth=SURGE * HOT + 8,
                               overlap_invocations=False, control=ctl))


def _round(loop: ServingLoop, queries, hot: int, cold: int):
    """Submit one round of classed demand with hot and cold arrivals
    interleaved (a real crowd is mixed — pre-shed, hot requests queue
    behind cold ones), drain it, return (hot_tickets, cold_rejected)."""
    tickets, cold_rej = [], 0
    total = hot + cold
    for i in range(total):
        # spread the hot arrivals evenly through the crowd, so pre-shed
        # they genuinely queue behind it
        if (i + 1) * hot // total > i * hot // total:
            t = loop.submit(queries[i % len(queries)], cls="hot")
            if not isinstance(t, Rejection):
                tickets.append(t)
        else:
            r = loop.submit(queries[(i + 1) % len(queries)], cls="cold")
            if isinstance(r, Rejection):
                cold_rej += 1
    while loop.requests.depth() > 0:
        loop.pump()
    loop.pump()  # controller tick with the drained queue's samples
    return tickets, cold_rej


def run(report: Optional[Report] = None, n: int = BENCH_N) -> Report:
    report = report or Report()
    clk = _Clock()
    loop = _make_loop(n, clk)
    queries = [q for q, _ in workload_for("musicbrainz")]
    # shadow estimator over the same histogram the controller reads:
    # used to measure, per phase, the value the controller will actually
    # compare against its thresholds
    shadow = WindowedQuantile(loop._brownout._cw.window("hot").hist)

    def est_round(hot: int, cold: int = 0):
        shadow.advance()
        tickets, rej = _round(loop, queries, hot, cold)
        est = shadow.quantile(loop._brownout.cfg.breach_quantile)
        clk.advance(1.01)
        return tickets, rej, est

    try:
        # warm-up: bootstrap invocation + caches, outside every window.
        # Several rounds — the first post-bootstrap rounds run measurably
        # slower than the steady state the budget is calibrated against
        for _ in range(4):
            _round(loop, queries, HOT, 0)
            clk.advance(1.01)
        loop.pump()

        # -- baseline: hot-only, sustainable ---------------------------------
        base_lat: List[float] = []
        base_rounds: List[List[float]] = []
        base_ests: List[float] = []
        t0 = time.perf_counter()
        for _ in range(ROUNDS):
            tickets, _, est = est_round(HOT)
            lat = [t.latency_s for t in tickets]
            base_lat.extend(lat)
            base_rounds.append(lat)
            if est is not None:
                base_ests.append(est)
        base_wall = time.perf_counter() - t0
        base_qps = len(base_lat) / max(base_wall, 1e-9)
        # median across rounds of the per-round p99: robust to the one
        # slow round an OS hiccup produces, unlike a pooled p99 whose
        # top-1% IS that round
        base_p99 = float(np.median(
            [np.percentile(r, 99) for r in base_rounds]))

        # -- calibration: the post-shed steady state, in estimator space -----
        # the shed surge serves 3x hot with all cold rejected; measure
        # what the controller's estimator reports for exactly that load
        hold_ests: List[float] = []
        for _ in range(CALIBRATION_ROUNDS):
            _, _, est = est_round(SURGE_HOT * HOT)
            if est is not None:
                hold_ests.append(est)
        est_base = float(np.median(base_ests))
        est_hold = float(np.median(hold_ests))
        # place the clear threshold at the geometric midpoint of the two
        # states: recovery windows clear it, shed windows hold above it
        thr = float(np.sqrt(est_base * est_hold))
        budget = thr / CLEAR_RATIO
        loop._brownout.set_budget("hot", budget)

        # -- flash crowd: 4x demand, a quarter of it cold --------------------
        surge_lat: List[List[float]] = []
        cold_rejected = 0
        hot_done = 0
        t0 = time.perf_counter()
        for _ in range(ROUNDS):
            tickets, rej, _ = est_round(SURGE_HOT * HOT, (SURGE - SURGE_HOT) * HOT)
            surge_lat.append([t.latency_s for t in tickets])
            hot_done += len(tickets)
            cold_rejected += rej
        surge_wall = time.perf_counter() - t0
        goodput = hot_done / max(surge_wall, 1e-9)
        peak_shed = loop.stats()["shed_level"]
        shed_raises = loop._brownout.shed_raises
        # the post-shed steady state: the back half of the surge
        # (median-of-rounds, same robust estimator as the baseline)
        late_p99 = float(np.median(
            [np.percentile(xs, 99) for xs in surge_lat[ROUNDS // 2:]]))

        # -- recovery: load drops; the first all-clear window re-opens -------
        # two baseline rounds inside one controller window (no clock
        # advance between them): the first window that observes the drop
        # holds 2*HOT samples, so one slow request cannot push its
        # quantile estimate back over the clear threshold
        _round(loop, queries, HOT, 0)
        _round(loop, queries, HOT, 0)
        clk.advance(1.01)
        loop.pump()  # the tick on the first full post-drop window
        after_one_window = loop.stats()["shed_level"]
        reopen_windows = 1
        while loop.stats()["shed_level"] > 0 and reopen_windows < 8:
            _round(loop, queries, HOT, 0)
            _round(loop, queries, HOT, 0)  # same 2-round window as above
            clk.advance(1.01)
            loop.pump()
            reopen_windows += 1
        cold_ok = not isinstance(loop.submit(queries[0], cls="cold"),
                                 Rejection)

        ratio = goodput / max(base_qps, 1e-9)
        report.add(
            "overload/baseline", 1.0 / max(base_qps, 1e-9),
            f"n={n} hot_qps={base_qps:.1f} p99={base_p99 * 1e3:.2f}ms "
            f"budget={budget * 1e3:.2f}ms",
            {"hot_qps": base_qps, "p99_s": base_p99, "budget_s": budget,
             "est_base_s": est_base, "est_hold_s": est_hold})
        report.add(
            "overload/flash_crowd", 1.0 / max(goodput, 1e-9),
            f"n={n} goodput={goodput:.1f}/s ratio={ratio:.2f}x "
            f"target>={GOODPUT_FLOOR}x shed_level={peak_shed} "
            f"cold_rejected={cold_rejected} "
            f"late_p99={late_p99 * 1e3:.2f}ms",
            {"goodput_qps": goodput, "goodput_ratio": ratio,
             "peak_shed_level": peak_shed, "shed_raises": shed_raises,
             "cold_rejected": cold_rejected, "late_p99_s": late_p99})
        report.add(
            "overload/recovery", 1e-6 * max(reopen_windows, 1),
            f"n={n} shed_after_one_window={after_one_window} "
            f"reopen_windows={reopen_windows} cold_admitted={cold_ok}",
            {"shed_after_one_window": after_one_window,
             "reopen_windows": reopen_windows,
             "cold_admitted": int(cold_ok)})

        if _STANDALONE:
            assert est_hold > est_base, (
                f"calibration failed: the 3x-hot state "
                f"({est_hold * 1e3:.2f}ms) is not separable from the "
                f"baseline ({est_base * 1e3:.2f}ms) in estimator space")
            assert shed_raises >= 1 and peak_shed >= 1, (
                "the 4x surge never engaged the brownout controller")
            assert cold_rejected > 0, (
                "brownout engaged but no cold request was shed")
            assert late_p99 <= budget, (
                f"hot p99 {late_p99 * 1e3:.2f}ms still over the "
                f"{budget * 1e3:.2f}ms budget in the post-shed steady "
                "state — shedding did not defend the SLO")
            assert ratio >= GOODPUT_FLOOR, (
                f"hot goodput collapsed under the surge: {goodput:.1f}/s "
                f"vs {base_qps:.1f}/s baseline ({ratio:.2f}x < "
                f"{GOODPUT_FLOOR}x)")
            assert after_one_window < peak_shed, (
                "admission did not start re-opening within one controller "
                f"window of the load dropping (level {after_one_window})")
            assert loop.stats()["shed_level"] == 0 and cold_ok, (
                f"admission never fully re-opened "
                f"({reopen_windows} windows)")
    finally:
        loop.stop()
    return report


if __name__ == "__main__":
    run().emit()
