"""Crash-safe serving: snapshot cost, WAL replay catch-up, degraded floor.

Acceptance benchmark for the durability layer (``repro.serve.snapshot`` /
``repro.serve.faults``).  Three claims, measured on inline-driven serving
loops:

* **snapshot cost** — capturing the full serving state (graph arrays,
  partition, sketch, counters, mutation log) is a host-side copy measured
  separately from the atomic publish, because only the capture runs on the
  serving worker; the write itself can happen on the snapshotter's
  background thread.
* **replay catch-up** — restore = latest snapshot + journal replay; the
  replay of a mutation tail must not take materially longer than applying
  it live did.  Asserted (standalone runs): replay wall <= 4x the live
  apply wall for the same batches.
* **degraded-mode throughput floor** — with a *permanent* injected
  invocation fault (every TAPER attempt dies; retry backoff and the
  backend ladder engage), the loop must keep answering queries at >= 25%
  of the fault-free throughput on the same stream.  Asserted (standalone
  runs).

Scale via ``REPRO_BENCH_N`` (default 20000).
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time
from typing import List, Optional, Tuple

_STANDALONE = "jax" not in sys.modules

from benchmarks.common import K, Report, workload_for
from repro.core.online import OnlinePolicy
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.serve import ServeLoopConfig, ServingLoop
from repro.serve.faults import SITE_INVOCATION, FaultInjector, InjectedFault
from repro.serve.snapshot import capture_serving_state
from repro.workload.stream import GraphMutationStream, WorkloadStream

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "20000"))
#: request budget per serving phase (degraded-floor comparison)
REQUESTS = int(os.environ.get("REPRO_RECOVERY_REQUESTS", "160"))
#: mutation batches in the replay catch-up tail
TAIL_BATCHES = int(os.environ.get("REPRO_RECOVERY_TAIL", "40"))
MICRO_BATCH = 16


def _serving_policy() -> OnlinePolicy:
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=8, min_interval=1,
                        dirty_fraction=0.05, drift_l1=0.6)


def _loop(n: int, snapdir: Optional[str],
          faults: Optional[FaultInjector] = None) -> ServingLoop:
    g = musicbrainz_like(n, avg_degree=6.0, seed=17)
    return ServingLoop(
        g, K, taper_config=TaperConfig(max_iterations=3),
        policy=_serving_policy(),
        config=ServeLoopConfig(
            micro_batch=MICRO_BATCH, overlap_invocations=False,
            snapshot_dir=snapdir, snapshot_on_commit=False,
            invocation_retry_backoff_s=0.01, faults=faults))


def _mutation_schedule(g, n_batches: int) -> List:
    scratch = g.copy()
    muts = GraphMutationStream(
        mode="mixed", seed=7,
        vertices_per_tick=max(2, g.n // 4000),
        edges_per_tick=max(8, g.m // 4000))
    out = []
    for _ in range(n_batches):
        b = muts.next_batch(scratch)
        scratch.apply_mutations(b)
        out.append(b)
    return out


def _serve(loop: ServingLoop, budget: int) -> Tuple[float, int]:
    """Inline-drive at least ``budget`` requests; returns (wall_s, served).
    Injected invocation faults surface through ``pump`` *after* the batch
    was answered, so the driver absorbs them (as a resilient client would)
    and progress is read back from the loop's completion counter."""
    ws = WorkloadStream(
        [q for q, _ in workload_for("musicbrainz")], period=6.0, seed=3)
    done0 = loop.metrics.completed
    t0 = time.perf_counter()
    while loop.metrics.completed - done0 < budget:
        ws.advance(0.1)
        backlog = budget - (loop.metrics.completed - done0)
        for q in ws.sample(min(MICRO_BATCH, backlog)):
            loop.submit(q)
        try:
            loop.pump()
        except InjectedFault:
            pass
    return time.perf_counter() - t0, loop.metrics.completed - done0


def run(report: Optional[Report] = None, n: int = BENCH_N) -> Report:
    report = report or Report()
    tmp = tempfile.mkdtemp(prefix="repro_recovery_")
    try:
        # -- phase 1+2: snapshot cost and replay catch-up --------------------
        loop = _loop(n, tmp)
        _serve(loop, REQUESTS // 2)              # reach a realistic state
        schedule = _mutation_schedule(loop.g, TAIL_BATCHES)

        t0 = time.perf_counter()
        state = capture_serving_state(loop.ot, loop.stats()["journal_seq"])
        capture_s = time.perf_counter() - t0
        loop.snapshot(sync=True)
        snap = loop._snapshotter
        report.add(
            "recovery/snapshot", snap.last_wall_s,
            f"n={loop.g.n} capture_ms={1e3 * capture_s:.2f} "
            f"publish_ms={1e3 * snap.last_wall_s:.2f} "
            f"bytes={snap.last_bytes} arrays={len(state.arrays)}",
            metrics={"capture_s": capture_s, "publish_s": snap.last_wall_s,
                     "bytes": snap.last_bytes})

        # the tail: applied live (journaled at each drain), then replayed
        t0 = time.perf_counter()
        for b in schedule:
            assert loop.submit_mutations(b) is True
            loop.pump()
        live_apply_s = time.perf_counter() - t0
        live_version = loop.g.version
        loop.stop()                               # flush + close the WAL

        t0 = time.perf_counter()
        restored = ServingLoop.restore(
            tmp, taper_config=TaperConfig(max_iterations=3),
            policy=_serving_policy(),
            config=ServeLoopConfig(micro_batch=MICRO_BATCH,
                                   overlap_invocations=False,
                                   snapshot_on_commit=False))
        restore_total_s = time.perf_counter() - t0
        res = restored.restore_result
        assert restored.g.version == live_version, "replay lost mutations"
        assert res.replayed >= 1 and res.replay_failed == 0
        rate = res.replayed / max(res.replay_wall_s, 1e-9)
        report.add(
            "recovery/replay_catchup", res.replay_wall_s,
            f"replayed={res.replayed} live_apply_s={live_apply_s:.3f} "
            f"replay_s={res.replay_wall_s:.3f} rate={rate:.0f}bat/s "
            f"restore_total_s={restore_total_s:.3f} target<=4x_live",
            metrics={"replayed": res.replayed, "replay_s": res.replay_wall_s,
                     "live_apply_s": live_apply_s,
                     "restore_total_s": restore_total_s})
        if _STANDALONE:
            # bounded catch-up: replay must not run materially slower than
            # the live apply did (it skips serving, journaling and triggers;
            # the additive slack absorbs timer noise at tiny scales)
            assert res.replay_wall_s <= 4.0 * live_apply_s + 0.25, (
                f"journal replay took {res.replay_wall_s:.3f}s for a tail "
                f"applied live in {live_apply_s:.3f}s")
        restored.stop()

        # -- phase 3: degraded-mode throughput floor -------------------------
        base = _loop(n, None)
        base_wall, base_served = _serve(base, REQUESTS)
        base.stop()
        base_qps = base_served / max(base_wall, 1e-9)

        fi = FaultInjector()
        fi.arm(SITE_INVOCATION, times=-1)          # every attempt dies
        hurt = _loop(n, None, faults=fi)
        hurt_wall, hurt_served = _serve(hurt, REQUESTS)
        stats = hurt.stats()
        hurt_qps = hurt_served / max(hurt_wall, 1e-9)
        floor = hurt_qps / max(base_qps, 1e-9)
        report.add(
            "recovery/degraded_floor", hurt_wall / max(hurt_served, 1),
            f"faultfree_qps={base_qps:.1f} degraded_qps={hurt_qps:.1f} "
            f"floor={floor:.2f}x target>=0.25x "
            f"faults_fired={fi.fired_total()} "
            f"failures={stats['invocation_failures']:.0f} healthy="
            f"{stats['healthy']:.0f}",
            metrics={"base_qps": base_qps, "degraded_qps": hurt_qps,
                     "floor": floor, "faults_fired": fi.fired_total()})
        assert fi.fired_total() >= 1, "fault injection never engaged"
        assert hurt_served >= REQUESTS, \
            "loop stopped answering queries under permanent invocation faults"
        if _STANDALONE:
            assert floor >= 0.25, (
                f"degraded-mode throughput fell to {floor:.2f}x of the "
                "fault-free baseline (floor: 0.25x)")
        # ``hurt`` is left unstopped on purpose: the latest invocation
        # failure is still pending, and stop() correctly re-raises it; the
        # inline loop holds no threads or files to release.
        return report
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    run().emit()
