import os
import sys

_STANDALONE = "jax" not in sys.modules
if _STANDALONE and "host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # force the shard devices BEFORE jax's first init (it locks the device
    # count); standalone runs get an 8-way host mesh, run.py invocations
    # (jax already initialised by an earlier benchmark) keep what exists
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ.get("REPRO_SERVE_LOOP_DEVICES", "8")
        + " " + os.environ.get("XLA_FLAGS", "")).strip()

__doc__ = """Async serving loop: overlap win over stop-the-world + warm shards.

Acceptance benchmark for the ``repro.serve`` subsystem.  Two engines serve
the *same* request/mutation stream (identical seeds, identical pre-generated
mutation schedule) with ``OnlineTaper``-triggered TAPER invocations running
the sharded extroversion field (``field_backend="pallas_sharded"``) on an
8-way forced-host mesh:

* **async** — the production configuration: invocations execute on a
  dedicated thread while the worker keeps serving micro-batches against the
  old partition vector, committing with one atomic swap;
* **sync** — the same loop with ``overlap_invocations=False``: the worker
  blocks for every invocation (the seed-era stop-the-world engine), so the
  bounded request queue backs up and admission rejects with retry hints.

Claims measured (asserted):

* sustained query throughput *during* a TAPER invocation (completions
  inside invocation windows / in-flight seconds) is **>= 2x** the sync
  baseline's sustained throughput on the same stream — asserted only when
  run standalone (this module controls the device count); under
  ``benchmarks/run.py`` the ratio is reported but not gated, like
  ``field_shard``'s speedup target;
* a mutation batch localized to one shard's vertex range re-uploads **only
  the dirty shard slices** (via ``pre["_shard_uploads"]``), never the whole
  packing (device-count independent: always asserted).

Scale via ``REPRO_BENCH_N`` (default 20000), ``REPRO_SERVE_LOOP_DEVICES``
(default 8; standalone runs only).
"""

import time
from typing import List, Optional

import numpy as np

from benchmarks.common import K, Report, workload_for
from repro.core.online import OnlinePolicy
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.serve import ServeLoopConfig, ServingLoop
from repro.serve.metrics import ServeMetrics
from repro.workload.stream import GraphMutationStream, WorkloadStream

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "20000"))
#: request budget of the measured phase (the sync run replays the same)
BUDGET = int(os.environ.get("REPRO_SERVE_LOOP_REQUESTS", "600"))
MICRO_BATCH = 16
QUEUE_DEPTH = 128
IN_FLIGHT = 64          # feeder keeps this many requests outstanding
MUTATION_EVERY = 50     # submit one schedule batch per this many requests
WARMUP = 32


def _mutation_schedule(g, n_batches: int) -> List[MutationBatch]:
    """Pre-generate the topology stream against a scratch copy so both
    engines ingest the *identical* batch sequence."""
    scratch = g.copy()
    muts = GraphMutationStream(
        mode="mixed", seed=7,
        vertices_per_tick=max(2, g.n // 4000),
        edges_per_tick=max(8, g.m // 4000))
    out = []
    for _ in range(n_batches):
        b = muts.next_batch(scratch)
        scratch.apply_mutations(b)
        out.append(b)
    return out


def _make_loop(n: int, overlap: bool, mesh) -> ServingLoop:
    g = musicbrainz_like(n, avg_degree=6.0, seed=13)
    loop = ServingLoop(
        g, K,
        taper_config=TaperConfig(
            max_iterations=3, field_backend="pallas_sharded"),
        policy=OnlinePolicy(
            bootstrap_after_ticks=0, cadence=6, min_interval=1,
            dirty_fraction=0.02, drift_l1=0.6),
        config=ServeLoopConfig(
            micro_batch=MICRO_BATCH, max_queue_depth=QUEUE_DEPTH,
            overlap_invocations=overlap, batch_wait_s=0.002))
    # one shared mesh -> one jitted shard_map across both engine runs
    loop.ot.taper._pre["_mesh"] = mesh
    return loop


def _submit_with_retry(loop: ServingLoop, q, rejections: List[int],
                       max_tries: int = 1000):
    for _ in range(max_tries):
        t = loop.submit(q)
        if t.accepted:
            return t
        rejections[0] += 1
        time.sleep(min(t.retry_after_s, 0.02))
    raise RuntimeError("request never admitted")


def _drive(loop: ServingLoop, budget: int, schedule: List[MutationBatch]):
    """Feed ``budget`` requests (top-up to IN_FLIGHT outstanding) plus the
    mutation schedule; return (wall_s, tickets, rejections)."""
    ws = WorkloadStream(
        [q for q, _ in workload_for("musicbrainz")], period=6.0, seed=3)
    tickets: List = []
    rejections = [0]
    sched = list(schedule)
    t0 = time.perf_counter()
    offered = 0
    while offered < budget:
        # top the in-flight window up (bounded, so the run is backlog-
        # limited rather than dumping the whole budget into the queue)
        pending = sum(1 for t in tickets if not t.done.is_set())
        chunk = min(budget - offered, max(0, IN_FLIGHT - pending))
        if chunk == 0:
            time.sleep(0.001)
            continue
        ws.advance(chunk / 100.0)
        for q in ws.sample(chunk):
            tickets.append(_submit_with_retry(loop, q, rejections))
        offered += chunk
        while sched and offered >= (len(schedule) - len(sched) + 1) * MUTATION_EVERY:
            loop.submit_mutations(sched.pop(0))
    for t in tickets:
        t.wait(timeout=600.0)
    wall = time.perf_counter() - t0
    return wall, tickets, rejections[0]


def run(report: Optional[Report] = None, n: int = BENCH_N) -> Report:
    import jax

    from repro.launch.mesh import make_smoke_mesh

    report = report or Report()
    n_dev = len(jax.devices())
    mesh = make_smoke_mesh()
    schedule_len = BUDGET // MUTATION_EVERY

    results = {}
    for name, overlap in (("async", True), ("sync", False)):
        loop = _make_loop(n, overlap, mesh)
        schedule = _mutation_schedule(loop.g, schedule_len)
        loop.start()
        # warm-up: bootstrap invocation + jit compile outside the clock
        warm = _drive(loop, WARMUP, [])
        for t in warm[1]:
            assert t.done.is_set()
        while loop.invocation_in_flight:
            time.sleep(0.005)
        loop.metrics = ServeMetrics(loop.cfg.metrics_window)

        wall, tickets, rejections = _drive(loop, BUDGET, schedule)
        stats = loop.stop()
        stats["wall_s"] = wall
        stats["rejections"] = rejections
        stats["invocations_total"] = loop.ot.invocations
        results[name] = (loop, stats)
        report.add(
            f"serve_loop/{name}_serving", wall / max(stats["completed"], 1),
            f"n={loop.g.n} devices={n_dev} completed={stats['completed']:.0f} "
            f"invocations={stats['invocations']:.0f} "
            f"rejected={rejections} "
            f"p50_ms={1e3 * stats['latency_p50_s']:.2f} "
            f"p99_ms={1e3 * stats['latency_p99_s']:.2f} "
            f"p99_ipt={stats['ipt_p99']:.1f} "
            f"stall_s={stats['invocation_stall_s']:.2f} "
            f"overlap_s={stats['invocation_overlap_s']:.2f}")

    a = results["async"][1]
    s = results["sync"][1]
    assert a["invocations"] >= 1, "async run never invoked TAPER"
    assert s["invocations"] >= 1, "sync run never invoked TAPER"
    # -- the overlap win ----------------------------------------------------
    tput_during_inv = (a["completed_during_invocation"]
                       / max(a["invocation_overlap_s"], 1e-9))
    tput_sync = s["completed"] / max(s["wall_s"], 1e-9)
    ratio = tput_during_inv / max(tput_sync, 1e-9)
    report.add(
        "serve_loop/overlap_win", 0.0,
        f"during_invocation_qps={tput_during_inv:.1f} "
        f"sync_sustained_qps={tput_sync:.1f} ratio={ratio:.2f}x "
        f"target>=2x served_during_inv={a['completed_during_invocation']:.0f}")
    if _STANDALONE:
        assert ratio >= 2.0, (
            f"overlapped serving during invocations must sustain >= 2x the "
            f"stop-the-world baseline, got {ratio:.2f}x")

    # -- localized ingest re-uploads only dirty shards ----------------------
    loop = results["async"][0]           # stopped; pump inline from here
    pre = loop.ot.taper._pre
    ups = pre["_shard_uploads"]
    rebuilds0, total0 = ups["rebuilds"], ups["total_shards"]
    # first shard's vertex range, capped at real vertices (n_local_pad is
    # block-padded and can exceed g.n on small shard counts)
    lim = min(loop.g.vm_packing_sharded(n_dev).n_local_pad, loop.g.n)
    rng = np.random.default_rng(0)
    ends = rng.integers(0, max(lim - 1, 1), (8, 2))
    loop.submit_mutations(MutationBatch(add_edges=ends))
    loop.pump()                          # apply ingest + warm dirty shards
    uploaded = ups["total_shards"] - total0
    report.add(
        "serve_loop/dirty_shard_ingest", 0.0,
        f"dirty_shards_uploaded={uploaded}/{n_dev} "
        f"scratch_rebuilds={ups['rebuilds'] - rebuilds0}")
    assert ups["rebuilds"] == rebuilds0, \
        "localized ingest must patch the packing, not re-pack from scratch"
    assert uploaded >= 1 and (n_dev == 1 or uploaded < n_dev), (
        f"localized ingest batch re-uploaded {uploaded}/{n_dev} shards — "
        "expected only the dirty subset")
    return report


if __name__ == "__main__":
    run().emit()
