"""Fig. 10 — quality degradation of a fitted partitioning under workload drift.

ProvGen dataset, two-query stream: Q_a = Entity.Entity at 100% linearly down
to 0%, Q_b = Agent.Activity up to 100% (§6.2.4).  The partitioning is
pre-fitted to 100% Q_a.  Claims: ipt rises as Q_b takes over, approaching
hash-partitioning quality; the dotted reference lines are (top) Q_b over
hash and (bottom) Q_b over a TAPER partitioning fitted to Q_b.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report, baselines, dataset, taper_for
from repro.core.rpq import parse_rpq
from repro.workload.executor import QueryExecutor
from repro.workload.stream import linear_drift

QA = parse_rpq("Entity.Entity")
QB = parse_rpq("Agent.Activity")
STEPS = 6


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    g = dataset("provgen")
    ex = QueryExecutor(g)
    hash_p, _ = baselines(g)
    taper = taper_for(g)

    t0 = time.perf_counter()
    fitted_a = taper.invoke(hash_p, [(QA, 1.0)]).final_part   # pre-improved for Qa
    fitted_b = taper.invoke(hash_p, [(QB, 1.0)]).final_part   # oracle for Qb
    fit_dt = time.perf_counter() - t0

    ipt_b_hash = ex.ipt(QB, hash_p)          # top dotted line
    ipt_b_fitted = ex.ipt(QB, fitted_b)      # bottom dotted line
    report.add("fig10/ref_hash", fit_dt, f"ipt_Qb_over_hash={ipt_b_hash:.0f}")
    report.add("fig10/ref_fitted", fit_dt, f"ipt_Qb_over_fitted={ipt_b_fitted:.0f}")

    # ipt(w_t, fitted_a) / ipt(w_t, hash): < 1 means the fitted partitioning
    # still has an advantage over hash; -> 1 means the advantage is gone
    # ("TAPER's quality improvement may degrade to near that of a naive
    # hash-partitioner", §6.2.4)
    ratios = []
    for i in range(STEPS + 1):
        t = i / STEPS
        fa, fb = linear_drift(t)
        w = [(QA, fa), (QB, fb)]
        ipt = ex.workload_ipt(w, fitted_a)
        ipt_hash = ex.workload_ipt(w, hash_p)
        ratio = ipt / max(ipt_hash, 1e-9)
        ratios.append(ratio)
        report.add(
            f"fig10/t{i}", 0.0,
            f"freq_Qb={fb:.2f} ipt={ipt:.0f} ipt_hash={ipt_hash:.0f} "
            f"vs_hash={ratio:.3f}",
        )
    restorable = ex.ipt(QB, fitted_b) / max(ipt_b_hash, 1e-9)
    report.add(
        "fig10/degradation", 0.0,
        f"vs_hash_start={ratios[0]:.3f} vs_hash_end={ratios[-1]:.3f} "
        f"restorable_floor={restorable:.3f} "
        f"degraded={ratios[-1] > ratios[0] * 1.5}",
    )
    return report


if __name__ == "__main__":
    run().emit()
