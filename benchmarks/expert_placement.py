"""Beyond-paper integration: TAPER expert placement for MoE (olmoe-style
64-expert, 16-layer) — cross-device co-routing mass before/after.

Routing statistics are synthesised with latent token clusters (tokens of a
cluster prefer a coherent expert subset per layer), the structure real MoE
routers exhibit and the reason placement matters.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report
from repro.core.expert_placement import plan_expert_placement

N_EXPERTS = 64
N_LAYERS = 8          # co-routing graph over 8 consecutive MoE layers
TOP_K = 4
N_TOKENS = 2048
N_DEVICES = 8


def synth_routing(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n_clusters = 16
    cluster = rng.integers(0, n_clusters, N_TOKENS)
    # each (cluster, layer) prefers a coherent subset of experts
    pref = rng.integers(0, N_EXPERTS, (n_clusters, N_LAYERS, TOP_K * 2))
    ids = np.empty((N_TOKENS, N_LAYERS, TOP_K), np.int64)
    for t in range(N_TOKENS):
        for l in range(N_LAYERS):
            pool = pref[cluster[t], l]
            pick = rng.choice(pool, TOP_K, replace=False)
            # 10% exploration outside the cluster preference
            explore = rng.random(TOP_K) < 0.1
            pick = np.where(explore, rng.integers(0, N_EXPERTS, TOP_K), pick)
            ids[t, l] = pick
    return ids


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    t0 = time.perf_counter()
    ids = synth_routing()
    plan = plan_expert_placement(ids, N_EXPERTS, N_DEVICES)
    dt = time.perf_counter() - t0
    before, after = plan["cross_mass_before"], plan["cross_mass_after"]
    report.add(
        "expert_placement/summary", dt,
        f"cross_device_coactivation before={before:.0f} after={after:.0f} "
        f"reduction={1 - after / max(before, 1e-9):.1%} "
        f"moves={plan['moves']} iters={plan['iterations']}",
    )
    return report


if __name__ == "__main__":
    run().emit()
