"""Beyond-paper integration: TAPER embedding-row placement for DLRM —
average query span (shards touched per request, SWORD's metric) under
hash vs TAPER-refined placement of hot rows.

Rows co-accessed by one request form the co-access graph (labels = field
ids); a request is a bag of lookups, i.e. short label paths — the direct
recsys analogue of the paper's workload (DESIGN.md §4.2).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report
from repro.configs.registry import get_config
from repro.core.rpq import concat, label
from repro.core.taper import Taper, TaperConfig
from repro.data.recsys import ClickLogPipeline
from repro.graphs.partition import hash_partition
from repro.models.dlrm import coaccess_graph, query_span

K = 64  # embedding shards (26 lookups over 64 shards: span is the latency driver)


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    cfg = get_config("dlrm-rm2").reduced()
    # scale vocabs up a bit so hot rows spread over shards
    import dataclasses

    cfg = dataclasses.replace(
        cfg, vocab_sizes=tuple(min(v, 1000) for v in
                               get_config("dlrm-rm2").vocab_sizes))
    pipe = ClickLogPipeline(cfg, batch=1024, seed=0, n_segments=32,
                            p_segment=0.95)
    batches = [next(pipe)["sparse"] for _ in range(4)]

    t0 = time.perf_counter()
    # cover the full (reduced) vocab so the placement governs every lookup
    g, row_of_vertex = coaccess_graph(cfg, batches, max_rows_per_field=1000)
    # workload: every co-access field pair (a request touches all 26 fields,
    # so all ordered pairs are legal 2-step traversals)
    w = [(concat(label(f"F{i}"), label(f"F{j}")), 1.0)
         for i in range(cfg.n_sparse) for j in range(cfg.n_sparse) if i != j]
    w = [(q, 1.0 / len(w)) for q, _ in w]

    part0 = hash_partition(g.n, K, seed=1)
    taper = Taper(g, K, TaperConfig(max_iterations=5, balance_eps=0.2,
                                    family_max_size=26, seed=0))
    part1 = taper.invoke(part0, w).final_part
    dt = time.perf_counter() - t0

    # map vertex partitions back to row placements; unseen rows stay hashed
    total_rows = cfg.total_rows()
    place0 = hash_partition(total_rows, K, seed=1)
    place1 = place0.copy()
    place1[row_of_vertex] = part1

    eval_batches = [next(pipe)["sparse"] for _ in range(4)]
    span0 = np.mean([query_span(place0, b, K) for b in eval_batches])
    span1 = np.mean([query_span(place1, b, K) for b in eval_batches])
    report.add("dlrm_span/hash", dt, f"avg_query_span={span0:.3f}")
    report.add("dlrm_span/taper", dt, f"avg_query_span={span1:.3f}")
    report.add("dlrm_span/summary", dt,
               f"span_reduction={1 - span1 / span0:.1%} "
               f"coaccess_graph_n={g.n} edges={g.undirected_edge_count()}")
    return report


if __name__ == "__main__":
    run().emit()
