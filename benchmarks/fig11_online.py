"""Fig. 11 — ipt over a streaming workload with periodic TAPER invocations.

MusicBrainz dataset; query frequencies drift periodically (sin-wave
complement, §6.1.2).  The TPSTry is maintained online from a frequency
sketch; TAPER is invoked at regular intervals on the *current* partitioning.
Claim: periodic invocations keep ipt below the drifting hash baseline and
each invocation is followed by a drop in ipt.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import MQ, Report, baselines, dataset, taper_for
from repro.workload.executor import QueryExecutor
from repro.workload.sketch import FrequencySketch
from repro.workload.stream import WorkloadStream

TICKS = 12
INVOKE_EVERY = 4
BATCH = 400


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    g = dataset("musicbrainz")
    ex = QueryExecutor(g)
    hash_p, _ = baselines(g)
    taper = taper_for(g, max_iterations=4)

    stream = WorkloadStream(list(MQ.values()), period=float(TICKS), seed=3)
    # observe_batch advances the decay clock once per batch, so the half
    # life is measured in batches (ticks), not individual observations
    sketch = FrequencySketch(half_life=2.0)

    # start from a partitioning fitted to the t=0 workload
    part = taper.invoke(hash_p, stream.workload()).final_part

    drops = 0
    invocations = 0
    prev_ipt = None
    t_spent = 0.0
    for tick in range(TICKS):
        stream.advance(1.0)
        sketch.observe_batch(stream.sample(BATCH))
        w_true = stream.workload()
        ipt_now = ex.workload_ipt(w_true, part)
        ipt_hash = ex.workload_ipt(w_true, hash_p)  # drifting baseline trendline
        invoked = ""
        if (tick + 1) % INVOKE_EVERY == 0:
            # invoke TAPER on the *current* partitioning with the *sketched*
            # workload (the online loop of eqn. 2)
            w_sketch = sketch.workload()
            t0 = time.perf_counter()
            part = taper.invoke(part, w_sketch).final_part
            t_spent += time.perf_counter() - t0
            invocations += 1
            ipt_after = ex.workload_ipt(w_true, part)
            if ipt_after < ipt_now:
                drops += 1
            invoked = f" invoked ipt_after={ipt_after:.0f}"
            ipt_now = ipt_after
        report.add(
            f"fig11/tick{tick}", t_spent / max(invocations, 1),
            f"ipt={ipt_now:.0f} hash_baseline={ipt_hash:.0f} "
            f"below_baseline={ipt_now < ipt_hash}{invoked}",
        )
        prev_ipt = ipt_now
    report.add(
        "fig11/summary", t_spent / max(invocations, 1),
        f"invocations={invocations} drops_after_invocation={drops}",
    )
    return report


if __name__ == "__main__":
    run().emit()
