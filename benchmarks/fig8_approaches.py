"""Fig. 8 — ipt per partitioning approach.

Approaches: Hash, Hash+TAPER, Metis(-like), Metis+TAPER (paper), plus
Fennel and Fennel+TAPER (extra streaming baseline).  Paper claim: TAPER
achieves ~30% average ipt reduction over a Metis starting point (§6.2.2).
"""
from __future__ import annotations

import time
from typing import Optional

from benchmarks.common import Report, baselines, dataset, taper_for, workload_for
from repro.graphs.partition import fennel_stream_partition
from repro.workload.executor import QueryExecutor


def run(report: Optional[Report] = None, datasets=("provgen", "musicbrainz")) -> Report:
    report = report or Report()
    for name in datasets:
        g = dataset(name)
        w = workload_for(name)
        ex = QueryExecutor(g)
        hash_p, metis_p = baselines(g)
        fennel_p = fennel_stream_partition(g, 8, seed=0)

        starts = {"hash": hash_p, "metis": metis_p, "fennel": fennel_p}
        ipts = {}
        for sname, part in starts.items():
            ipts[sname] = ex.workload_ipt(w, part)
            report.add(f"fig8/{name}/{sname}", 0.0, f"ipt={ipts[sname]:.0f}")

        taper = taper_for(g)
        for sname, part in starts.items():
            t0 = time.perf_counter()
            rep = taper.invoke(part, w)
            dt = time.perf_counter() - t0
            ipt = ex.workload_ipt(w, rep.final_part)
            report.add(
                f"fig8/{name}/{sname}+taper", dt,
                f"ipt={ipt:.0f} reduction={1 - ipt / max(ipts[sname], 1e-9):.1%} "
                f"iters={rep.iterations} moves={rep.total_moves}",
            )
    return report


if __name__ == "__main__":
    run().emit()
