"""Swap-engine scaling: vectorised vs seed swap, per-phase invocation split.

Acceptance benchmark for the frontier-batched swap engine
(repro.core.swap): on a 50k-vertex, k=8 synthetic graph one internal
iteration's swap phase must be >= 5x faster than the seed per-vertex
implementation (repro.core.swap_ref), with bit-identical partitions.

Also reports the per-phase split of a full invocation — extroversion field
vs swap — and the resulting moves/sec, which is the number that governs how
far internal iterations scale (paper §5: iterations must stay inexpensive).

Scale via REPRO_SWAP_BENCH_N (default 50000); runs standalone or from
benchmarks/run.py.
"""
from __future__ import annotations

import os
import time
from typing import Optional

import numpy as np

from benchmarks.common import Report, dataset, workload_for
from repro.core.swap import SwapConfig, swap_iteration
from repro.core.swap_ref import swap_iteration_reference
from repro.core.taper import Taper, TaperConfig
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.partition import hash_partition

BENCH_N = int(os.environ.get("REPRO_SWAP_BENCH_N", "50000"))
K = 8


def run(report: Optional[Report] = None, n: int = BENCH_N, k: int = K) -> Report:
    report = report or Report()
    g = dataset("musicbrainz", n=n)
    w = workload_for("musicbrainz")
    arrays = TPSTry.from_workload(w).compile(g.label_names)
    part = hash_partition(g.n, k, seed=1)

    # -- one-off graph caches (reverse index + kernel packing) --------------
    t0 = time.perf_counter()
    g.reverse_edge_index
    report.add("swap_scale/reverse_edge_index", time.perf_counter() - t0,
               f"m={g.m}")

    # -- field phase --------------------------------------------------------
    pre = {}
    t0 = time.perf_counter()
    fld = extroversion_field(g, arrays, part, k, _precomputed=pre)
    t_field_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    fld = extroversion_field(g, arrays, part, k, _precomputed=pre)
    t_field = time.perf_counter() - t0
    report.add("swap_scale/field_cold", t_field_cold, "jit compile + device put")
    report.add("swap_scale/field_warm", t_field, "device-resident inputs")

    # -- swap phase: vectorised vs seed ------------------------------------
    cfg = SwapConfig()
    t_new = []
    for _ in range(3):
        t0 = time.perf_counter()
        p_new, s_new = swap_iteration(g, part, fld, k, cfg,
                                      np.random.default_rng(0))
        t_new.append(time.perf_counter() - t0)
    t_new = min(t_new)
    t0 = time.perf_counter()
    p_ref, s_ref = swap_iteration_reference(g, part, fld, k, cfg,
                                            np.random.default_rng(0))
    t_ref = time.perf_counter() - t0

    identical = bool((p_new == p_ref).all()) and s_new == s_ref
    speedup = t_ref / max(t_new, 1e-9)
    report.add(
        "swap_scale/swap_vectorised", t_new,
        f"n={g.n} k={k} moves={s_new.moves} candidates={s_new.candidates} "
        f"moves_per_sec={s_new.moves / max(t_new, 1e-9):.0f}",
    )
    report.add("swap_scale/swap_seed", t_ref,
               f"moves_per_sec={s_ref.moves / max(t_ref, 1e-9):.0f}")
    report.add(
        "swap_scale/summary", t_new + t_field,
        f"speedup={speedup:.1f}x identical={identical} "
        f"field_frac={t_field / max(t_field + t_new, 1e-9):.2f} "
        f"swap_frac={t_new / max(t_field + t_new, 1e-9):.2f}",
    )

    # -- full-invocation per-phase split -----------------------------------
    taper = Taper(g, k, TaperConfig(max_iterations=3, seed=0))
    import repro.core.taper as taper_mod

    phase = {"field": 0.0, "swap": 0.0, "moves": 0}
    orig_swap = taper_mod.swap_iteration
    orig_field = taper_mod.extroversion_field

    def timed_swap(*a, **kw):
        t0 = time.perf_counter()
        out = orig_swap(*a, **kw)
        phase["swap"] += time.perf_counter() - t0
        phase["moves"] += out[1].moves
        return out

    def timed_field(*a, **kw):
        t0 = time.perf_counter()
        out = orig_field(*a, **kw)
        phase["field"] += time.perf_counter() - t0
        return out

    taper_mod.swap_iteration = timed_swap
    taper_mod.extroversion_field = timed_field
    try:
        rep = taper.invoke(part, arrays)
    finally:
        taper_mod.swap_iteration = orig_swap
        taper_mod.extroversion_field = orig_field
    total = phase["field"] + phase["swap"]
    report.add(
        "swap_scale/invoke_phases", total,
        f"iters={rep.iterations} field_s={phase['field']:.3f} "
        f"swap_s={phase['swap']:.3f} moves={phase['moves']} "
        f"moves_per_sec={phase['moves'] / max(phase['swap'], 1e-9):.0f}",
    )
    return report


if __name__ == "__main__":
    rep = run()
    rep.emit()
    summary = [r for r in rep.rows if r.name == "swap_scale/summary"][0]
    assert "identical=True" in summary.derived, summary.derived
    speedup = float(summary.derived.split("speedup=")[1].split("x")[0])
    assert speedup >= 5.0, f"swap speedup {speedup}x < 5x acceptance floor"
    print(f"\nACCEPTANCE OK: {summary.derived}")
