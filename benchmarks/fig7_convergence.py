"""Fig. 7 — ipt per TAPER internal iteration, from a hash partitioning.

Paper claims (§6.2.1): quality converges to within ~10% of a Metis
partitioning in < 8 internal iterations, with ~80% ipt reduction on ProvGen;
and the total number of vertex swaps is at least 2x smaller than the cost of
rearranging the hash partitioning into the Metis one (swap-cost comparison).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report, baselines, dataset, taper_for, workload_for
from repro.workload.executor import QueryExecutor


def run(report: Optional[Report] = None, datasets=("provgen", "musicbrainz")) -> Report:
    report = report or Report()
    for name in datasets:
        g = dataset(name)
        w = workload_for(name)
        ex = QueryExecutor(g)
        hash_p, metis_p = baselines(g)
        ipt_hash = ex.workload_ipt(w, hash_p)     # top dotted line
        ipt_metis = ex.workload_ipt(w, metis_p)   # bottom dotted line

        taper = taper_for(g)
        t0 = time.perf_counter()
        rep = taper.invoke(hash_p, w)
        dt = time.perf_counter() - t0

        # ipt per internal iteration (the plotted series)
        series = [ex.workload_ipt(w, p) for p in rep.parts]
        for i, v in enumerate(series):
            report.add(
                f"fig7/{name}/iter{i}", dt / max(rep.iterations, 1),
                f"ipt={v:.0f} frac_of_hash={v / ipt_hash:.3f}",
            )
        final = series[-1]
        reduction = 1 - final / ipt_hash
        vs_metis = final / max(ipt_metis, 1e-9)
        report.add(
            f"fig7/{name}/summary", dt,
            f"iters={rep.iterations} reduction={reduction:.1%} "
            f"ipt_hash={ipt_hash:.0f} ipt_metis={ipt_metis:.0f} vs_metis={vs_metis:.2f}",
        )

        # §6.2.1 swap-cost comparison: swaps TAPER needs to reach Metis-level
        # quality vs the cost of rearranging the hash partitioning into the
        # Metis one ("a Metis repartitioning has a cost at least 2X that of a
        # TAPER invocation").
        swaps_to_metis_quality = rep.total_moves
        cum = 0
        for i, moves in enumerate(rep.moves):
            cum += moves
            if series[i + 1] <= ipt_metis:
                swaps_to_metis_quality = cum
                break
        metis_rearrange = int((hash_p != metis_p).sum())
        report.add(
            f"fig7/{name}/swap_cost", dt,
            f"taper_swaps_total={rep.total_moves} "
            f"taper_swaps_to_metis_quality={swaps_to_metis_quality} "
            f"metis_rearrange_swaps={metis_rearrange} "
            f"ratio={metis_rearrange / max(swaps_to_metis_quality, 1):.2f}x",
        )
    return report


if __name__ == "__main__":
    run().emit()
