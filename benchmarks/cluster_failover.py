"""Replicated cluster serving: catch-up replay, failover, degraded reads.

Acceptance benchmark for the replication layer (``repro.serve.replication``
/ ``repro.serve.cluster``).  Three claims, measured on an inline-driven
cluster (one primary ``ServingLoop`` + WAL-shipped followers):

* **follower catch-up replay** — a follower cut off behind a link
  partition re-applies the missed WAL tail through the hub's journal-backed
  tail resync; that replay must not take materially longer than the
  primary's live apply of the same batches did.  Asserted (standalone
  runs): catch-up wall <= 4x the live apply wall.
* **failover-to-first-answer** — from the instant the primary dies to the
  first successfully served read off the promoted follower: heartbeat
  timeout + promotion (catch-up, epoch-opening commit, device warm, fresh
  snapshot) + one routed read.  Asserted (standalone runs): bounded by the
  heartbeat timeout plus a fixed promotion budget.
* **degraded read throughput** — with one follower crashed, reads routed
  to it redirect to the primary; cluster read throughput must hold >= 0.5x
  the all-replicas-healthy rate on the same stream.  Asserted (standalone
  runs).

The drill is the timed twin of ``tests/test_cluster.py``'s bitwise one:
the same crash -> promote -> serve sequence, with wall clocks on each leg.
Scale via ``REPRO_BENCH_N`` (default 20000).
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time
from typing import List, Optional, Tuple

import numpy as np

_STANDALONE = "jax" not in sys.modules

from benchmarks.common import K, Report, workload_for
from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.serve import (
    ClusterConfig,
    ClusterCoordinator,
    ServeLoopConfig,
    ServingLoop,
)
from repro.serve.faults import FaultInjector, SITE_LINK_PARTITION
from repro.workload.stream import GraphMutationStream, WorkloadStream

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "20000"))
#: read budget per throughput phase
REQUESTS = int(os.environ.get("REPRO_CLUSTER_REQUESTS", "96"))
#: mutation batches in the catch-up tail
TAIL_BATCHES = int(os.environ.get("REPRO_CLUSTER_TAIL", "40"))
HB_TIMEOUT_S = 0.2
#: promotion budget on top of heartbeat detection (catch-up + epoch-open
#: commit + device warm + fresh snapshot + one read)
PROMOTION_BUDGET_S = 5.0
MICRO_BATCH = 16


def _policy(quiet: bool = False) -> OnlinePolicy:
    """``quiet=True`` freezes invocations after the bootstrap one, so the
    throughput phases measure the read path, not invocation scheduling."""
    if quiet:
        return OnlinePolicy(bootstrap_after_ticks=0, cadence=10 ** 9,
                            min_interval=10 ** 9, dirty_fraction=1.0,
                            drift_l1=9e9, ipt_regression=9e9)
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=8, min_interval=1,
                        dirty_fraction=0.05, drift_l1=9e9,
                        ipt_regression=9e9)


def _cluster(n: int, tmp: str, n_followers: int = 2,
             faults: Optional[FaultInjector] = None,
             quiet: bool = False) -> ClusterCoordinator:
    g = musicbrainz_like(n, avg_degree=6.0, seed=17)
    primary = ServingLoop(
        g, K, taper_config=TaperConfig(max_iterations=3),
        policy=_policy(quiet),
        config=ServeLoopConfig(micro_batch=MICRO_BATCH,
                               overlap_invocations=False,
                               snapshot_dir=tmp, faults=faults))
    return ClusterCoordinator(
        primary,
        config=ClusterConfig(n_followers=n_followers,
                             heartbeat_timeout_s=HB_TIMEOUT_S,
                             faults=faults),
        policy=_policy(quiet), taper_config=TaperConfig(max_iterations=3))


def _serve_reads(coord: ClusterCoordinator, budget: int, seed: int,
                 queries: Optional[List] = None) -> Tuple[float, int]:
    """Inline-drive ``budget`` routed reads; returns (wall_s, served)."""
    ws = WorkloadStream(
        queries if queries is not None
        else [q for q, _ in workload_for("musicbrainz")],
        period=6.0, seed=seed)
    served = 0
    t0 = time.perf_counter()
    while served < budget:
        ws.advance(0.1)
        batch = ws.sample(min(8, budget - served))
        coord.serve(batch, cls="hot")
        served += len(batch)
    return time.perf_counter() - t0, served


def _mutation_tail(coord: ClusterCoordinator, n_batches: int):
    g = coord.primary.g
    scratch = g.copy()
    muts = GraphMutationStream(
        mode="mixed", seed=7,
        vertices_per_tick=max(2, g.n // 4000),
        edges_per_tick=max(8, g.m // 4000))
    out = []
    for _ in range(n_batches):
        b = muts.next_batch(scratch)
        scratch.apply_mutations(b)
        out.append(b)
    return out


def run(report: Optional[Report] = None, n: int = BENCH_N) -> Report:
    report = report or Report()
    tmp = tempfile.mkdtemp(prefix="repro_cluster_")
    try:
        # -- phase 1: follower catch-up replay <= 4x live apply --------------
        fi = FaultInjector()
        coord = _cluster(n, os.path.join(tmp, "catchup"), n_followers=1,
                         faults=fi, quiet=True)
        _serve_reads(coord, 16, seed=1)          # bootstrap invocation fires
        coord.pump()
        f = coord.followers[1]
        f.catch_up()
        fi.arm(f"{SITE_LINK_PARTITION}:replica-1")
        tail = _mutation_tail(coord, TAIL_BATCHES)
        t0 = time.perf_counter()
        for b in tail:
            assert coord.submit_mutations(b) is True
            coord.pump()
        live_apply_s = time.perf_counter() - t0
        behind = f.seq_lag
        assert behind >= TAIL_BATCHES, "follower was not actually cut off"
        fi.disarm(f"{SITE_LINK_PARTITION}:replica-1")
        t0 = time.perf_counter()
        while f.seq_lag > 0:
            f.catch_up()
        catchup_s = time.perf_counter() - t0
        st = f.stats()
        assert st["tail_resyncs"] >= 1 and st["full_resyncs"] == 0, \
            "catch-up went through a snapshot re-fetch, not tail replay"
        report.add(
            "cluster/catchup_replay", catchup_s,
            f"batches={behind} live_apply_s={live_apply_s:.3f} "
            f"catchup_s={catchup_s:.3f} "
            f"rate={behind / max(catchup_s, 1e-9):.0f}bat/s target<=4x_live",
            metrics={"batches": behind, "live_apply_s": live_apply_s,
                     "catchup_s": catchup_s})
        if _STANDALONE:
            assert catchup_s <= 4.0 * live_apply_s + 0.25, (
                f"follower catch-up took {catchup_s:.3f}s for a tail the "
                f"primary applied live in {live_apply_s:.3f}s")
        coord.stop()

        # -- phase 2: failover-to-first-answer --------------------------------
        coord = _cluster(n, os.path.join(tmp, "failover"), n_followers=2)
        _serve_reads(coord, 32, seed=2)
        for b in _mutation_tail(coord, 8):
            coord.submit_mutations(b)
            coord.pump()
        q0 = workload_for("musicbrainz")[0][0]
        t0 = time.perf_counter()
        coord.crash_primary()
        while coord.failovers == 0:
            coord.pump()
            time.sleep(0.01)
        detect_promote_s = time.perf_counter() - t0
        res = coord.serve([q0], cls="hot")
        first_answer_s = time.perf_counter() - t0
        assert len(res) == 1 and res[0] is not None
        assert coord.stats()["cluster_epoch"] == 2
        report.add(
            "cluster/failover_first_answer", first_answer_s,
            f"hb_timeout_s={HB_TIMEOUT_S} "
            f"detect+promote_s={detect_promote_s:.3f} "
            f"first_answer_s={first_answer_s:.3f} epoch=2 "
            f"target<=hb+{PROMOTION_BUDGET_S:.0f}s",
            metrics={"detect_promote_s": detect_promote_s,
                     "first_answer_s": first_answer_s,
                     "hb_timeout_s": HB_TIMEOUT_S})
        if _STANDALONE:
            assert first_answer_s <= HB_TIMEOUT_S + PROMOTION_BUDGET_S, (
                f"failover-to-first-answer took {first_answer_s:.3f}s "
                f"(budget {HB_TIMEOUT_S + PROMOTION_BUDGET_S:.2f}s)")
        coord.stop()

        # -- phase 3: read throughput with one crashed replica ----------------
        coord = _cluster(n, os.path.join(tmp, "degraded"), n_followers=2,
                         quiet=True)
        _serve_reads(coord, 16, seed=3)          # warm: bootstrap + caches
        # TAPER clusters the core workload's start labels together, so the
        # stock mix can majority-route every query to one slot.  Extend the
        # mix with reads starting from follower-owned labels so the healthy
        # phase spreads across replicas and the crash actually reroutes work.
        g = coord.primary.g
        own = coord.router.owners()
        mix = [q for q, _ in workload_for("musicbrainz")]
        for lab in range(g.n_labels):
            vs = np.nonzero(g.labels == lab)[0]
            if vs.size == 0:
                continue
            slot = int(np.argmax(np.bincount(own[vs],
                                             minlength=coord.n_replicas)))
            if slot != coord.primary_slot:
                mix.append(parse_rpq(
                    f"{g.label_names[lab]}.{g.label_names[lab]}"))
        healthy_wall, healthy_served = _serve_reads(coord, REQUESTS, seed=4,
                                                    queries=mix)
        healthy_qps = healthy_served / max(healthy_wall, 1e-9)
        # crash the follower carrying the most routed reads, so the degraded
        # phase exercises the dead-redirect path
        by_slot = dict(coord.router.routed_by_slot)
        victim = max(coord.followers, key=lambda s: by_slot.get(s, 0))
        assert by_slot.get(victim, 0) > 0, \
            f"owner routing sent no reads to any follower ({by_slot})"
        coord.followers[victim].crash()
        hurt_wall, hurt_served = _serve_reads(coord, REQUESTS, seed=5,
                                              queries=mix)
        hurt_qps = hurt_served / max(hurt_wall, 1e-9)
        ratio = hurt_qps / max(healthy_qps, 1e-9)
        rst = coord.router.stats()
        report.add(
            "cluster/degraded_reads", hurt_wall / max(hurt_served, 1),
            f"healthy_qps={healthy_qps:.1f} one_down_qps={hurt_qps:.1f} "
            f"ratio={ratio:.2f}x target>=0.5x "
            f"dead_redirects={rst['dead_redirects']}",
            metrics={"healthy_qps": healthy_qps, "one_down_qps": hurt_qps,
                     "ratio": ratio,
                     "dead_redirects": rst["dead_redirects"]})
        if _STANDALONE:
            assert rst["dead_redirects"] >= 1, \
                "no read ever routed to the crashed replica (vacuous run)"
            assert ratio >= 0.5, (
                f"read throughput fell to {ratio:.2f}x of healthy with one "
                "crashed replica (floor: 0.5x)")
        coord.stop()
        return report
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    run().emit()
