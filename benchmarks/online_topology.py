"""Online TAPER under combined topology + workload drift.

The missing half of Fig. 11: the graph itself churns and grows
(``GraphMutationStream``, mixed scenario) while query frequencies drift
(§6.1.2 periodic model).  An ``OnlineTaper`` maintains the partitioning —
greedy arrival placement per tick, policy-gated (mutation-local) invocations
— against the drifting hash baseline (new vertices hashed like everyone
else).

Claims measured:

* ipt of the OnlineTaper partitioning stays below the hash baseline while
  the topology drifts underneath it;
* per-tick *incremental* cache maintenance (merge-patched edge arrays /
  reverse index / label counts + delta-patched executor traversal counts)
  is cheaper than rebuilding those structures from scratch each tick.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import K, MQ, Report, dataset, taper_for
from repro.core.online import OnlinePolicy, OnlineTaper
from repro.graphs.graph import LabelledGraph
from repro.graphs.partition import hash_partition
from repro.workload.executor import QueryExecutor
from repro.workload.stream import GraphMutationStream, WorkloadStream

TICKS = 10
BATCH = 300


def _rebuild_from_scratch(g: LabelledGraph, queries) -> float:
    """Cost of the non-incremental alternative: rebuild every maintained
    structure from the raw edge list (fresh sort + CSR, reverse index,
    neighbour-label counts, full executor DP per query)."""
    t0 = time.perf_counter()
    fresh = LabelledGraph(
        n=g.n, labels=g.labels.copy(), label_names=g.label_names,
        src=g.src.copy(), dst=g.dst.copy())
    fresh.reverse_edge_index
    fresh.cached_neighbor_label_counts()
    ex = QueryExecutor(fresh)
    for q in queries:
        ex.traversals(q)
    return time.perf_counter() - t0


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    g = dataset("musicbrainz").copy()  # this benchmark mutates its graph
    queries = list(MQ.values())

    ex = QueryExecutor(g)
    stream = WorkloadStream(queries, period=float(TICKS), seed=3)
    muts = GraphMutationStream(
        mode="mixed", seed=7,
        vertices_per_tick=max(2, g.n // 2000),
        edges_per_tick=max(8, g.m // 2000))

    # start from a partitioning fitted to the t=0 workload
    taper0 = taper_for(g, max_iterations=4)
    part0 = taper0.invoke(
        hash_partition(g.n, K, seed=1), stream.workload()).final_part
    # dirty_fraction is set so the topology trigger needs a few ticks of
    # accumulated churn — ticks without an invocation (greedy placement
    # only) and the cadence/drift triggers are part of what's measured
    online = OnlineTaper(
        g, K, part=part0, config=taper0.config,
        policy=OnlinePolicy(cadence=4, dirty_fraction=0.05, drift_l1=0.35))
    online.observe(stream.sample(BATCH))
    for q in queries:  # warm the DP cache: the loop times *patching* only
        ex.traversals(q)

    below = 0
    t_incr_total = 0.0
    t_rebuild_total = 0.0
    for tick in range(TICKS):
        stream.advance(1.0)
        online.observe(stream.sample(BATCH))
        batch = muts.next_batch(g)

        # incremental maintenance: merge-patch the graph's own caches and
        # delta-patch the executor's traversal counts.  Only cache
        # maintenance is timed — partition placement (online.ingest) runs
        # outside the clock so the rebuild comparison is like-for-like
        t0 = time.perf_counter()
        applied = g.apply_mutations(batch)
        g.reverse_edge_index
        g.cached_neighbor_label_counts()
        for q in queries:
            ex.traversals(q)
        t_incr = time.perf_counter() - t0
        online.ingest(applied)
        t_rebuild = _rebuild_from_scratch(g, queries)
        t_incr_total += t_incr
        t_rebuild_total += t_rebuild

        w_true = stream.workload()
        ipt_now = ex.workload_ipt(w_true, online.part)
        step = online.step(measured_ipt=ipt_now)
        if step.invoked:
            ipt_now = ex.workload_ipt(w_true, online.part)
        hash_p = hash_partition(g.n, K, seed=1)  # drifting baseline
        ipt_hash = ex.workload_ipt(w_true, hash_p)
        below += ipt_now < ipt_hash
        report.add(
            f"online_topology/tick{tick}", t_incr,
            f"n={g.n} m={g.m} ipt={ipt_now:.0f} hash_baseline={ipt_hash:.0f} "
            f"below_baseline={ipt_now < ipt_hash} "
            f"invoked={step.invoked} reason={step.reason or '-'} "
            f"maint_incr_ms={1e3 * t_incr:.2f} "
            f"maint_rebuild_ms={1e3 * t_rebuild:.2f}",
        )
    speedup = t_rebuild_total / max(t_incr_total, 1e-12)
    report.add(
        "online_topology/summary", t_incr_total / TICKS,
        f"ticks={TICKS} below_baseline={below}/{TICKS} "
        f"invocations={online.invocations} "
        f"incremental_vs_rebuild_speedup={speedup:.2f}x "
        f"all_below_baseline={below == TICKS}",
    )
    return report


if __name__ == "__main__":
    run().emit()
