import os
import sys

if "jax" not in sys.modules and "host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # force the shard devices BEFORE jax's first init (it locks the device
    # count); standalone runs get an 8-way host mesh, run.py invocations
    # (jax already initialised by an earlier benchmark) keep what exists
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ.get("REPRO_FIELD_SHARD_DEVICES", "8")
        + " " + os.environ.get("XLA_FLAGS", "")).strip()

__doc__ = """Sharded multi-device extroversion field: scaling + halo traffic.

Acceptance benchmark for ``extroversion_field(backend="pallas_sharded")``:
on an 8-way (forced host device) mesh the sharded backend's warm
per-invocation field time must beat the single-device ``pallas`` backend,
and the PR-5 claim: dealing shards along the live TAPER partition vector
(``shard_map_source="partition"``) with the two-tier sliced halo exchange
must cut the bytes moved per depth step by **>= 2x** against the PR-3
baseline (id-striped shard map + psum'd union frontier, halo ratio 0.876),
at numerical parity with the jnp oracle on *both* exchange backends.

Reported rows:

* ``field_shard/single_device_warm`` / ``field_shard/sharded_warm`` — warm
  per-invocation wall time of each backend (same graph, same trie), the
  sharded row on the PR-3 stripe+psum configuration;
* ``field_shard/speedup`` — single/sharded ratio on this host;
* ``field_shard/halo_exchange`` — per-shard bytes per depth step of the
  stripe+psum baseline vs a full-field exchange;
* ``field_shard/halo_sliced`` — the same graph under the partition shard
  map + sliced (hot union + ring pair slices) exchange: bytes per depth,
  the reduction factor vs the baseline (asserted >= 2x on an 8-way mesh,
  and partition-map halo ratio <= 0.5x the stripe baseline's — the CI
  bench-smoke gate), and the warm field time of the re-dealt layout;
* ``field_shard/patched_reinvoke`` — field time right after a *localized*
  mutation batch against the permuted packing, with how many of the S
  shards were re-uploaded (the delta-aware shard patching at work; a
  scratch re-pack would re-upload all of them).

Scale via ``REPRO_BENCH_N`` (default 50000) and
``REPRO_FIELD_SHARD_DEVICES`` (default 8; only effective standalone).
"""

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report, workload_for
from repro.core.taper import Taper, TaperConfig
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.graphs.partition import metis_like_partition

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "50000"))
K = 8
REPEATS = 3


def _time_invocations(fn, repeats=REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(report: Optional[Report] = None, n: int = BENCH_N, k: int = K) -> Report:
    import jax

    report = report or Report()
    n_dev = len(jax.devices())
    g = musicbrainz_like(n, avg_degree=6.0, seed=13)
    w = workload_for("musicbrainz")
    arrays = TPSTry.from_workload(w).compile(g.label_names)
    # the live-TAPER scenario: a metis-like seed enhanced by a short
    # invocation — this is both the partition the field is evaluated on and
    # the vector the "partition" shard map deals vertices by
    part = metis_like_partition(g, k, seed=0)
    part = Taper(g, k, TaperConfig(max_iterations=2, seed=0)).invoke(
        part, w).final_part
    depths = max(arrays.max_depth - 1, 1)  # DP steps per invocation

    # -- single-device pallas baseline -------------------------------------
    pre_single = {}
    t0 = time.perf_counter()
    extroversion_field(g, arrays, part, k, _precomputed=pre_single,
                       backend="pallas")
    t_single_cold = time.perf_counter() - t0
    t_single = _time_invocations(lambda: extroversion_field(
        g, arrays, part, k, _precomputed=pre_single, backend="pallas"))
    report.add("field_shard/single_device_warm", t_single,
               f"n={g.n} m={g.m} trie_N={arrays.n_nodes} "
               f"per_depth={1e3 * t_single / depths:.2f}ms",
               metrics={"n": g.n, "m": g.m, "trie_nodes": arrays.n_nodes})

    # -- sharded backend, PR-3 baseline configuration (stripe + psum) -------
    pre_shard = {}
    t0 = time.perf_counter()
    fld_sh = extroversion_field(g, arrays, part, k, _precomputed=pre_shard,
                                backend="pallas_sharded",
                                halo_exchange="psum")
    t_shard_cold = time.perf_counter() - t0
    t_shard = _time_invocations(lambda: extroversion_field(
        g, arrays, part, k, _precomputed=pre_shard,
        backend="pallas_sharded", halo_exchange="psum"))
    sp = g.vm_packing_sharded(n_dev)
    report.add("field_shard/sharded_warm", t_shard,
               f"devices={n_dev} shards={sp.n_shards} "
               f"per_depth={1e3 * t_shard / depths:.2f}ms "
               f"cold={t_shard_cold:.2f}s_vs_{t_single_cold:.2f}s",
               metrics={"devices": n_dev, "warm_s": t_shard,
                        "cold_s": t_shard_cold})

    speedup = t_single / max(t_shard, 1e-12)
    report.add("field_shard/speedup", t_single - t_shard,
               f"{speedup:.2f}x_single_over_sharded devices={n_dev} "
               f"target>=2x_at_8dev", metrics={"speedup": speedup})

    # -- parity guard (the speedup must be of the same answer) --------------
    fld_ref = extroversion_field(g, arrays, part, k, backend="jnp")
    err = float(np.abs(fld_ref.extroversion - fld_sh.extroversion).max())
    assert err < 1e-4, f"sharded field diverged from jnp oracle: {err}"

    # -- PR-3 baseline halo traffic vs full-field exchange ------------------
    halo_base = sp.halo_bytes_per_depth(arrays.n_nodes, exchange="psum")
    full = sp.full_field_bytes_per_depth(g.n, arrays.n_nodes)
    ratio_base = halo_base / full
    assert halo_base < full, "halo exchange must beat a full-field exchange"
    report.add("field_shard/halo_exchange", 0.0,
               f"halo_bytes={halo_base} full_field_bytes={full} "
               f"ratio={ratio_base:.3f} frontier={sp.n_frontier}/{g.n}",
               metrics={"halo_bytes_per_depth": halo_base,
                        "full_field_bytes_per_depth": full,
                        "halo_ratio": ratio_base,
                        "shard_map_source": "stripe",
                        "halo_exchange": "psum"})

    # -- PR-5: partition shard map + sliced exchange ------------------------
    pre_sliced = {}
    fld_sl = extroversion_field(g, arrays, part, k, _precomputed=pre_sliced,
                                backend="pallas_sharded",
                                shard_map_source="partition",
                                halo_exchange="sliced")
    err = float(np.abs(fld_ref.extroversion - fld_sl.extroversion).max())
    assert err < 1e-4, f"sliced-exchange field diverged from oracle: {err}"
    t_sliced = _time_invocations(lambda: extroversion_field(
        g, arrays, part, k, _precomputed=pre_sliced,
        backend="pallas_sharded", shard_map_source="partition",
        halo_exchange="sliced"))
    hs = pre_sliced["_halo_stats"]
    halo_sl, ratio_sl = hs["halo_bytes_per_depth"], hs["halo_ratio"]
    reduction = halo_base / max(halo_sl, 1)
    report.add("field_shard/halo_sliced", t_sliced,
               f"halo_bytes={halo_sl} ratio={ratio_sl:.3f} "
               f"reduction={reduction:.2f}x_vs_psum_union_baseline "
               f"hot_rows={hs['hot_rows']} sliced_rows={hs['sliced_rows']} "
               f"per_depth={1e3 * t_sliced / depths:.2f}ms target>=2x",
               metrics={"halo_bytes_per_depth": halo_sl,
                        "halo_ratio": ratio_sl,
                        "reduction_vs_baseline": reduction,
                        "shard_map_source": "partition",
                        "halo_exchange": "sliced",
                        "warm_s": t_sliced})
    if n_dev >= 8:
        # the PR-5 acceptance claim + the CI bench-smoke gate
        assert reduction >= 2.0, (
            f"partition shard map + sliced exchange must cut halo bytes per "
            f"depth >= 2x vs the psum'd-union baseline, got {reduction:.2f}x")
        assert ratio_sl <= 0.5 * ratio_base, (
            f"partition-map halo ratio {ratio_sl:.3f} must be <= 0.5x the "
            f"stripe baseline's {ratio_base:.3f}")

    # -- delta-aware shard patching on the permuted packing ----------------
    # a mutation localized to one shard's vertex range: the cached packing
    # is patched (dirty shards only), never re-packed from scratch
    token, order = pre_sliced["_shard_order"]
    sp_p = g.vm_packing_sharded(n_dev, order=order, order_token=token)
    owners = sp_p.owner_of(np.arange(g.n))
    shard0 = np.nonzero(owners == 0)[0]
    rng = np.random.default_rng(0)
    ends = shard0[rng.integers(0, shard0.size, (8, 2))]
    g.apply_mutations(MutationBatch(add_edges=ends))
    t0 = time.perf_counter()
    extroversion_field(g, arrays, part, k, _precomputed=pre_sliced,
                       backend="pallas_sharded",
                       shard_map_source="partition", halo_exchange="sliced")
    t_patched = time.perf_counter() - t0
    ups = pre_sliced["_shard_uploads"]
    report.add("field_shard/patched_reinvoke", t_patched,
               f"dirty_shards_uploaded={ups['last_shards']}/{sp_p.n_shards} "
               f"scratch_rebuilds={ups['rebuilds']}",
               metrics={"dirty_shards_uploaded": ups["last_shards"],
                        "n_shards": sp_p.n_shards,
                        "scratch_rebuilds": ups["rebuilds"]})
    return report


if __name__ == "__main__":
    run().emit()
