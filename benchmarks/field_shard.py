import os
import sys

if "jax" not in sys.modules and "host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    # force the shard devices BEFORE jax's first init (it locks the device
    # count); standalone runs get an 8-way host mesh, run.py invocations
    # (jax already initialised by an earlier benchmark) keep what exists
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ.get("REPRO_FIELD_SHARD_DEVICES", "8")
        + " " + os.environ.get("XLA_FLAGS", "")).strip()

__doc__ = """Sharded multi-device extroversion field: scaling + halo traffic.

Acceptance benchmark for ``extroversion_field(backend="pallas_sharded")``:
on an 8-way (forced host device) mesh at N >= 50k, k = 8, the sharded
backend's warm per-invocation field time must beat the single-device
``pallas`` backend by >= 2x, with the per-depth halo exchange moving
strictly fewer bytes than a full-field exchange would.

Reported rows:

* ``field_shard/single_device_warm`` / ``field_shard/sharded_warm`` — warm
  per-invocation wall time of each backend (same graph, same trie), with
  the per-depth split in the derived column;
* ``field_shard/speedup`` — single/sharded ratio on this host;
* ``field_shard/halo_exchange`` — bytes per shard per depth step actually
  exchanged (the psum'd frontier) vs what an all-gather of the full
  ``(n, N_trie)`` field would move;
* ``field_shard/patched_reinvoke`` — field time right after a *localized*
  mutation batch, with how many of the S shards were re-uploaded (the
  delta-aware shard patching at work; a scratch re-pack would re-upload
  all of them).

Scale via ``REPRO_BENCH_N`` (default 50000) and
``REPRO_FIELD_SHARD_DEVICES`` (default 8; only effective standalone).
"""

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report, workload_for
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.graphs.partition import hash_partition

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "50000"))
K = 8
REPEATS = 3


def _time_invocations(fn, repeats=REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(report: Optional[Report] = None, n: int = BENCH_N, k: int = K) -> Report:
    import jax

    report = report or Report()
    n_dev = len(jax.devices())
    g = musicbrainz_like(n, avg_degree=6.0, seed=13)
    w = workload_for("musicbrainz")
    arrays = TPSTry.from_workload(w).compile(g.label_names)
    part = hash_partition(g.n, k, seed=1)
    depths = max(arrays.max_depth - 1, 1)  # DP steps per invocation

    # -- single-device pallas baseline -------------------------------------
    pre_single = {}
    t0 = time.perf_counter()
    extroversion_field(g, arrays, part, k, _precomputed=pre_single,
                       backend="pallas")
    t_single_cold = time.perf_counter() - t0
    t_single = _time_invocations(lambda: extroversion_field(
        g, arrays, part, k, _precomputed=pre_single, backend="pallas"))
    report.add("field_shard/single_device_warm", t_single,
               f"n={g.n} m={g.m} trie_N={arrays.n_nodes} "
               f"per_depth={1e3 * t_single / depths:.2f}ms")

    # -- sharded backend ----------------------------------------------------
    pre_shard = {}
    t0 = time.perf_counter()
    fld_sh = extroversion_field(g, arrays, part, k, _precomputed=pre_shard,
                                backend="pallas_sharded")
    t_shard_cold = time.perf_counter() - t0
    t_shard = _time_invocations(lambda: extroversion_field(
        g, arrays, part, k, _precomputed=pre_shard,
        backend="pallas_sharded"))
    sp = g.vm_packing_sharded(n_dev)
    report.add("field_shard/sharded_warm", t_shard,
               f"devices={n_dev} shards={sp.n_shards} "
               f"per_depth={1e3 * t_shard / depths:.2f}ms "
               f"cold={t_shard_cold:.2f}s_vs_{t_single_cold:.2f}s")

    speedup = t_single / max(t_shard, 1e-12)
    report.add("field_shard/speedup", t_single - t_shard,
               f"{speedup:.2f}x_single_over_sharded devices={n_dev} "
               f"target>=2x_at_8dev")

    # -- parity guard (the speedup must be of the same answer) --------------
    fld_ref = extroversion_field(g, arrays, part, k, backend="jnp")
    err = float(np.abs(fld_ref.extroversion - fld_sh.extroversion).max())
    assert err < 1e-4, f"sharded field diverged from jnp oracle: {err}"

    # -- halo traffic vs full-field exchange --------------------------------
    halo = sp.halo_bytes_per_depth(arrays.n_nodes)
    full = sp.full_field_bytes_per_depth(g.n, arrays.n_nodes)
    assert halo < full, "halo exchange must beat a full-field exchange"
    report.add("field_shard/halo_exchange", 0.0,
               f"halo_bytes={halo} full_field_bytes={full} "
               f"ratio={halo / full:.3f} frontier={sp.n_frontier}/{g.n}")

    # -- delta-aware shard patching -----------------------------------------
    # a mutation localized to the first shard's vertex range: the cached
    # packing is patched (dirty shards only), never re-packed from scratch
    lim = sp.n_local_pad
    rng = np.random.default_rng(0)
    ends = rng.integers(0, max(lim - 1, 1), (8, 2))
    g.apply_mutations(MutationBatch(add_edges=ends))
    t0 = time.perf_counter()
    extroversion_field(g, arrays, part, k, _precomputed=pre_shard,
                       backend="pallas_sharded")
    t_patched = time.perf_counter() - t0
    ups = pre_shard["_shard_uploads"]
    report.add("field_shard/patched_reinvoke", t_patched,
               f"dirty_shards_uploaded={ups['last_shards']}/{sp.n_shards} "
               f"scratch_rebuilds={ups['rebuilds']}")
    return report


if __name__ == "__main__":
    run().emit()
