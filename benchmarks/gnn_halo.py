"""Beyond-paper integration: TAPER node placement for distributed GNN
training — halo-exchange bytes per forward pass under hash / metis-like /
TAPER placements.

The GNN's k-hop gather pattern IS a query workload over the node-type
graph: a 2-layer GCN traverses every edge twice per step, so the workload
is the label-closure of 2-step paths.  TAPER placement minimises exactly
the traversals that become halo rows (DESIGN.md §4.1).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import Report, dataset, taper_for
from repro.configs.registry import get_config
from repro.core.rpq import parse_rpq
from repro.graphs.partition import hash_partition, metis_like_partition
from repro.models.gnn.distributed import halo_bytes_per_step

K = 8


def gnn_workload(g):
    """k-hop message passing: every 2-label path is equally likely; weight
    by label frequency so TAPER optimises the actual gather volume."""
    names = g.label_names
    freqs = g.label_counts() / g.n
    out = []
    for i, a in enumerate(names):
        for b in names:
            w = float(freqs[i])
            if w > 0:
                out.append((parse_rpq(f"{a}.{b}"), w))
    total = sum(f for _, f in out)
    return [(q, f / total) for q, f in out]


def run(report: Optional[Report] = None) -> Report:
    report = report or Report()
    g = dataset("musicbrainz")
    cfg = get_config("gcn-cora")
    d_feat = 64

    hash_p = hash_partition(g.n, K, seed=1)
    metis_p = metis_like_partition(g, K, seed=0)
    w = gnn_workload(g)
    taper = taper_for(g, max_iterations=6)
    t0 = time.perf_counter()
    taper_p = taper.invoke(hash_p, w).final_part
    taper_m = taper.invoke(metis_p, w).final_part
    dt = time.perf_counter() - t0

    res = {}
    for name, part in [("hash", hash_p), ("metis", metis_p),
                       ("hash+taper", taper_p), ("metis+taper", taper_m)]:
        res[name] = halo_bytes_per_step(g, part, cfg, d_feat, K)
        report.add(f"gnn_halo/{name}", dt,
                   f"halo_bytes_per_fwd={res[name]} "
                   f"vs_hash={res[name] / max(res['hash'], 1):.3f}")
    report.add(
        "gnn_halo/summary", dt,
        f"taper_reduces_halo_vs_hash={1 - res['hash+taper'] / res['hash']:.1%} "
        f"vs_metis={1 - res['metis+taper'] / res['metis']:.1%}",
    )
    return report


if __name__ == "__main__":
    run().emit()
