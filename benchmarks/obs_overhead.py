import sys

_STANDALONE = "jax" not in sys.modules

__doc__ = """Observability overhead: tracing must be ~free when off, <=5% when on.

Acceptance benchmark for the ``repro.obs`` subsystem.  Three serving loops
answer the *same* request stream (identical seeds, inline pump, no
invocations inside the measured window) under three observability
configurations:

* **untraced** — no ``Observability`` bundle: the default
  ``Observability.disabled()`` fast path (one attribute check per call
  site, no allocation);
* **sampled-off** — an *enabled* bundle with ``trace_sample_rate=0``:
  recorder and registry live, but every trace's sampling decision is "no"
  (the production default when only metrics/flight-recorder are wanted);
* **traced** — ``trace_sample_rate=1.0``: every request and every
  invocation carries a full span tree.

Claims measured (asserted standalone; reported under ``run.py``):

* traced throughput is **>= 0.95x** untraced throughput on the same
  stream (the tentpole's <=5% overhead budget);
* sampled-off throughput is **>= 0.95x** untraced (rate 0 has no
  measurable cost beyond noise);
* the traced run actually produced spans, and its registry export
  round-trips through the Prometheus text format byte-identically.

Scale via ``REPRO_BENCH_N`` (default 20000 vertices) and
``REPRO_OBS_REQUESTS`` (default 400 requests per configuration).
"""

import os
import time
from typing import Dict, Optional, Tuple

from benchmarks.common import K, Report, workload_for
from repro.core.online import OnlinePolicy
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.obs import Observability, parse_prometheus_text
from repro.serve import ServeLoopConfig, ServingLoop
from repro.workload.stream import WorkloadStream

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "20000"))
REQUESTS = int(os.environ.get("REPRO_OBS_REQUESTS", "400"))
MICRO_BATCH = 16
WARMUP = 48
#: interleaved measurement rounds per configuration; best-of — the rounds
#: round-robin across configurations so machine-speed drift (frequency
#: scaling, noisy neighbours) hits every configuration equally
REPEATS = 5
OVERHEAD_FLOOR = 0.95


def _make_loop(n: int, obs: Optional[Observability]) -> ServingLoop:
    g = musicbrainz_like(n, avg_degree=6.0, seed=13)
    return ServingLoop(
        g, K,
        taper_config=TaperConfig(max_iterations=2),
        # bootstrap fires once during warm-up; the huge cadence keeps the
        # measured window invocation-free so it times the serve path alone
        policy=OnlinePolicy(bootstrap_after_ticks=0, cadence=10 ** 9,
                            min_interval=0, dirty_fraction=2.0,
                            drift_l1=9e9),
        config=ServeLoopConfig(micro_batch=MICRO_BATCH,
                               overlap_invocations=False, obs=obs))


def _serve(loop: ServingLoop, queries) -> float:
    """Admit + pump ``queries`` inline; returns the wall time."""
    t0 = time.perf_counter()
    tickets = []
    for q in queries:
        t = loop.submit(q)
        while not t.accepted:
            loop.pump()
            t = loop.submit(q)
        tickets.append(t)
        if len(tickets) % MICRO_BATCH == 0:
            loop.pump()
    while not all(t.done.is_set() for t in tickets):
        loop.pump()
    return time.perf_counter() - t0


def _measure(n: int, configs) -> Tuple[Dict[str, float], Dict[str, ServingLoop]]:
    """Best-of-``REPEATS`` throughput (req/s) per configuration, with the
    rounds interleaved across configurations (module doc)."""
    ws = WorkloadStream([q for q, _ in workload_for("musicbrainz")],
                        period=6.0, seed=3)
    stream = ws.sample(REQUESTS)
    loops, best = {}, {}
    for name, obs in configs:
        loops[name] = _make_loop(n, obs)
        _serve(loops[name], ws.sample(WARMUP))  # bootstrap + caches
        best[name] = 0.0
    for _ in range(REPEATS):
        for name in loops:
            wall = _serve(loops[name], stream)
            best[name] = max(best[name], REQUESTS / max(wall, 1e-9))
    return best, loops


def run(report: Optional[Report] = None, n: int = BENCH_N) -> Report:
    report = report or Report()

    qps, loops = _measure(n, [
        ("untraced", None),
        ("rate0", Observability(trace_sample_rate=0.0)),
        ("traced", Observability(trace_sample_rate=1.0)),
    ])
    untraced, rate0, traced = qps["untraced"], qps["rate0"], qps["traced"]
    rate0_loop, traced_loop = loops["rate0"], loops["traced"]

    r_traced = traced / max(untraced, 1e-9)
    r_rate0 = rate0 / max(untraced, 1e-9)
    report.add("obs_overhead/untraced", 1.0 / max(untraced, 1e-9),
               f"n={n} qps={untraced:.1f} requests={REQUESTS}")
    report.add("obs_overhead/sampled_off", 1.0 / max(rate0, 1e-9),
               f"n={n} qps={rate0:.1f} ratio={r_rate0:.3f}x "
               f"target>={OVERHEAD_FLOOR}x")
    report.add("obs_overhead/traced", 1.0 / max(traced, 1e-9),
               f"n={n} qps={traced:.1f} ratio={r_traced:.3f}x "
               f"target>={OVERHEAD_FLOOR}x "
               f"spans={len(traced_loop.obs.tracer.spans())}")

    # the traced run must actually have traced: every request sampled
    tr = traced_loop.obs.tracer
    assert tr.sampled_traces >= REQUESTS, (
        f"traced run sampled {tr.sampled_traces} traces for "
        f"{REQUESTS}+ requests")
    assert tr.spans(name="request"), "no request spans recorded"
    assert traced_loop.obs.tracer.spans(name="invocation"), \
        "warm-up bootstrap invocation left no trace"
    # rate-0 run must NOT have traced requests (that is what makes it
    # cheap); forced invocation traces still fire — they are rare and
    # load-bearing by design
    assert not rate0_loop.obs.tracer.spans(name="request")
    assert rate0_loop.obs.tracer.unsampled_traces > 0

    # registry export round-trips byte-identically through Prometheus text
    text = traced_loop.obs.registry.to_prometheus_text(
        include_collected=False)
    assert parse_prometheus_text(text).to_prometheus_text(
        include_collected=False) == text, "Prometheus round-trip diverged"

    if _STANDALONE:
        assert r_traced >= OVERHEAD_FLOOR, (
            f"full tracing costs more than the overhead budget: "
            f"{traced:.1f} vs {untraced:.1f} qps ({r_traced:.3f}x < "
            f"{OVERHEAD_FLOOR}x)")
        assert r_rate0 >= OVERHEAD_FLOOR, (
            f"trace_sample_rate=0 must be ~free: {rate0:.1f} vs "
            f"{untraced:.1f} qps ({r_rate0:.3f}x < {OVERHEAD_FLOOR}x)")
    return report


if __name__ == "__main__":
    run().emit()
