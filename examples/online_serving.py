"""End-to-end driver: serve a stream of batched RPQ requests over a
partitioned graph while TAPER maintains the partitioning online.

The workload drifts (sin-wave frequencies, paper §6.1.2); the engine's
drift-triggered TAPER invocations keep ipt-per-request low — this is the
paper's deployment mode (eqn. 2) as a running service.

    PYTHONPATH=src python examples/online_serving.py
"""
import numpy as np

from repro.core.rpq import parse_rpq
from repro.graphs.generators import provgen_like
from repro.graphs.partition import hash_partition
from repro.serve.engine import GraphQueryEngine, ServeConfig
from repro.workload.stream import WorkloadStream


def main():
    g = provgen_like(n=8_000, seed=3)
    k = 8
    queries = [
        parse_rpq("Entity.Entity.Entity"),
        parse_rpq("Agent.Activity.Entity"),
        parse_rpq("Entity.Activity.Agent"),
    ]
    stream = WorkloadStream(queries, period=8.0, seed=0)
    engine = GraphQueryEngine(
        g, hash_partition(g.n, k, seed=1), k,
        ServeConfig(min_requests_between_invocations=300,
                    drift_threshold=0.2),
    )

    print("tick | requests | ipt/request | invocations | drift")
    for tick in range(12):
        batch = stream.sample(100)
        results = engine.serve_batch(batch)
        ipt_tick = sum(r.ipt for r in results) / len(results)
        s = engine.stats()
        print(f"{tick:4d} | {s['requests']:8d} | {ipt_tick:11.2f} | "
              f"{s['invocations']:11d} | {s['drift']:.3f}")
        stream.advance(1.0)

    s = engine.stats()
    print(f"\nserved {s['requests']} requests, "
          f"{s['invocations']} online TAPER invocations, "
          f"avg ipt/request {s['ipt_per_request']:.2f}")


if __name__ == "__main__":
    main()
