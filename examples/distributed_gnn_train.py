"""Distributed-GNN integration: train a GCN whose nodes are placed by
TAPER, and compare the halo-exchange bytes the placement implies.

    PYTHONPATH=src python examples/distributed_gnn_train.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.gnn_halo import gnn_workload
from repro.configs.registry import get_config, shapes_for
from repro.core.taper import Taper, TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.partition import hash_partition
from repro.models.gnn import api as gnn_api
from repro.models.gnn.distributed import halo_bytes_per_step
from repro.optim import AdamW

K = 8


def main():
    g = musicbrainz_like(n=6_000, seed=2)
    cfg = get_config("gcn-cora").reduced()
    shape = shapes_for("gcn-cora")[0]

    # --- placement: hash vs TAPER (workload = the GCN's gather pattern) ---
    hash_p = hash_partition(g.n, K, seed=1)
    taper = Taper(g, K, TaperConfig(max_iterations=6))
    taper_p = taper.invoke(hash_p, gnn_workload(g)).final_part
    d_feat = 64
    h_hash = halo_bytes_per_step(g, hash_p, cfg, d_feat, K)
    h_taper = halo_bytes_per_step(g, taper_p, cfg, d_feat, K)
    print(f"halo bytes/step: hash={h_hash} taper={h_taper} "
          f"({1 - h_taper / h_hash:.1%} less exchange)")

    # --- train the GCN on this graph (node classification) ---
    rng = np.random.default_rng(0)
    batch = {
        "node_feat": jnp.asarray(rng.normal(size=(g.n, d_feat)).astype(np.float32) * 0.1),
        "edge_src": jnp.asarray(g.src),
        "edge_dst": jnp.asarray(g.dst),
        "node_mask": jnp.ones(g.n, bool),
        "edge_mask": jnp.ones(g.m, bool),
        "targets": jnp.asarray(g.labels % cfg.n_classes),
    }
    from repro.models.gnn import gcn

    params, _ = gcn.init(jax.random.PRNGKey(0), cfg, d_feat)
    opt = AdamW(learning_rate=5e-3, weight_decay=0.0)
    ostate = opt.init(params)

    @jax.jit
    def step(params, ostate, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: gcn.loss_fn(p, batch, cfg), has_aux=True)(params)
        params, ostate = opt.update(params, grads, ostate)
        return params, ostate, metrics

    for i in range(201):
        params, ostate, m = step(params, ostate, batch)
        if i % 50 == 0:
            print(f"step {i:4d}: loss={float(m['loss']):.4f} "
                  f"acc={float(m['accuracy']):.3f}")


if __name__ == "__main__":
    main()
