"""Train a small LM for a few hundred steps with the full production loop:
checkpointing, resume, straggler watchdog, optional gradient compression.

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 300] [--compress]
"""
import argparse
import tempfile

import jax

from repro.configs.registry import get_config
from repro.data.lm import TokenPipeline
from repro.models import transformer as tf
from repro.optim import AdamW, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("qwen3-4b").reduced()
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(learning_rate=cosine_schedule(3e-3, 20, args.steps))
    ostate = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} (reduced), {n_params/1e6:.2f}M params")

    step = jax.jit(tf.make_train_step(cfg, opt, remat=False))
    data = TokenPipeline(cfg.vocab, batch=8, seq_len=128, seed=0)

    def loss_and_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tf.loss_fn(p, batch, cfg), has_aux=True)(params)
        return grads, metrics

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                      checkpoint_dir=ckpt_dir, log_every=25,
                      compress_grads=args.compress),
        step, params, ostate, data,
        grad_step_fn=jax.jit(loss_and_grads),
        apply_fn=jax.jit(lambda p, g, o: opt.update(p, g, o)),
    )
    trainer.try_resume()  # crash-safe: picks up from the latest checkpoint
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(stragglers flagged: {len(out['stragglers'])}) ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
