"""Quickstart: enhance a partitioning with TAPER and measure the ipt drop.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.rpq import parse_rpq
from repro.core.taper import Taper, TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.metrics import edge_cut, partition_balance
from repro.graphs.partition import hash_partition
from repro.workload.executor import QueryExecutor


def main():
    # 1. a heterogeneous graph (12 vertex labels) and a query workload
    g = musicbrainz_like(n=10_000, seed=0)
    print(f"graph: {g.stats()}")
    workload = [
        (parse_rpq("Artist.Credit.(Track|Recording).Credit.Artist"), 0.3),
        (parse_rpq("Artist.Credit.Track.Medium"), 0.5),
        (parse_rpq("Area.Artist.(Artist|Label).Area"), 0.2),
    ]

    # 2. a starting partitioning (hash) and its quality
    k = 8
    part0 = hash_partition(g.n, k, seed=1)
    ex = QueryExecutor(g)
    ipt0 = ex.workload_ipt(workload, part0)
    print(f"hash partitioning: ipt={ipt0:.0f} cut={edge_cut(g, part0)}")

    # 3. one TAPER invocation
    taper = Taper(g, k, TaperConfig(max_iterations=8))
    report = taper.invoke(part0, workload)

    # 4. the enhanced partitioning
    part1 = report.final_part
    ipt1 = ex.workload_ipt(workload, part1)
    print(
        f"TAPER: {report.iterations} iterations, {report.total_moves} vertex "
        f"swaps\n  ipt {ipt0:.0f} -> {ipt1:.0f} ({1 - ipt1 / ipt0:.1%} lower)\n"
        f"  cut {edge_cut(g, part0)} -> {edge_cut(g, part1)} "
        f"(edge-cut is NOT the objective)\n"
        f"  balance: {partition_balance(part1, k):.3f} (max 1.05)"
    )


if __name__ == "__main__":
    main()
